//! Integration: the threaded `exec::DistRunner` computes THE SAME training
//! step as the sequential engines.
//!
//! For n ∈ {2, 4, 8} ranks on the native backend: loss, every parameter
//! gradient, and the per-rank hidden chunks of the threaded runner match
//! both the sequential `SeqParEngine` and the serial (single-device)
//! engine within 1e-4.  Two extra properties the threaded path must hold:
//!
//! * determinism — same seed, two runs ⇒ bit-identical results, no matter
//!   how the OS schedules the rank threads (the dataflow, not the thread
//!   interleaving, decides every float);
//! * meter parity — sequential and threaded runs record byte-identical
//!   ring-P2P and all-reduce traffic.

use seqpar::attn::AttnPattern;
use seqpar::backend::native::NativeConfig;
use seqpar::comm::{CommKind, Fabric, Meter};
use seqpar::exec::{DistRunner, RankFailure};
use seqpar::model::params::ParamStore;
use seqpar::model::BERT_TINY_Z4;
use seqpar::obs;
use seqpar::parallel::sequence::{SeqParEngine, SpStrategy};
use seqpar::parallel::tensorp::TensorParEngine;
use seqpar::parallel::{Batch, Engine, StepOutput};
use seqpar::runtime::Runtime;
use seqpar::tensor::ops;
use seqpar::train::data::{Corpus, CorpusConfig};

const TOL: f32 = 1e-4;

fn runtime(n: usize) -> Runtime {
    Runtime::native(NativeConfig { ring: n, ..NativeConfig::tiny() }).unwrap()
}

fn batch_for(rt: &Runtime, seed: u64) -> Batch {
    let m = rt.manifest();
    Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), seed)
        .next_batch()
        .unwrap()
}

fn assert_grads_close(tag: &str, a: &StepOutput, b: &StepOutput, tol: f32) {
    for (name, g) in &b.grads.values {
        let d = ops::max_abs_diff(&a.grads.values[name], g).unwrap();
        assert!(d < tol, "{tag}: grad {name} diverged, Δ={d}");
    }
}

#[test]
fn threaded_matches_sequential_and_serial() {
    for n in [2usize, 4, 8] {
        let rt = runtime(n);
        let m = rt.manifest().clone();
        let params = ParamStore::synthetic(&m);
        let batch = batch_for(&rt, 21);

        let serial = TensorParEngine::new(&rt, Fabric::new(1, Meter::new())).unwrap();
        let s = serial.forward_backward(&params, &batch).unwrap();

        let seq = SeqParEngine::new(&rt, Fabric::new(n, Meter::new())).unwrap();
        let q = seq.forward_backward(&params, &batch).unwrap();

        let dist = DistRunner::new(&rt, Meter::new()).unwrap();
        assert_eq!(dist.n, n);
        let t = dist.forward_backward(&params, &batch).unwrap();

        assert!(
            (t.loss - s.loss).abs() < TOL,
            "n={n}: threaded loss {} vs serial {}",
            t.loss,
            s.loss
        );
        assert!(
            (t.loss - q.loss).abs() < TOL,
            "n={n}: threaded loss {} vs sequential {}",
            t.loss,
            q.loss
        );
        assert_grads_close(&format!("n={n} threaded vs serial"), &t, &s, TOL);
        assert_grads_close(&format!("n={n} threaded vs sequential"), &t, &q, TOL);

        // hidden chunks: identical per-rank dataflow ⇒ match the
        // sequential simulation chunk by chunk...
        assert_eq!(t.hidden.len(), n);
        for (d, (th, qh)) in t.hidden.iter().zip(&q.hidden).enumerate() {
            let diff = ops::max_abs_diff(th, qh).unwrap();
            assert!(diff < TOL, "n={n}: hidden chunk {d} diverged, Δ={diff}");
        }
        // ...and reassemble to the serial hidden states
        let lc = m.seq_len / n;
        let chunks3d: Vec<_> = t
            .hidden
            .iter()
            .map(|h| h.clone().reshaped(&[m.batch, lc, m.hidden]).unwrap())
            .collect();
        let refs: Vec<_> = chunks3d.iter().collect();
        let full = ops::concat_dim(&refs, 1)
            .unwrap()
            .reshaped(&[m.batch * m.seq_len, m.hidden])
            .unwrap();
        let dh = ops::max_abs_diff(&full, &s.hidden[0]).unwrap();
        assert!(dh < TOL, "n={n}: reassembled hidden vs serial Δ={dh}");
    }
}

/// Same seed, two threaded runs ⇒ identical bits, regardless of how the
/// OS interleaves the rank threads.
#[test]
fn threaded_run_is_deterministic() {
    let n = 4;
    let rt = runtime(n);
    let params = ParamStore::synthetic(rt.manifest());
    let batch = batch_for(&rt, 33);

    let dist = DistRunner::new(&rt, Meter::new()).unwrap();
    let a = dist.forward_backward(&params, &batch).unwrap();
    let b = dist.forward_backward(&params, &batch).unwrap();

    assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss not bit-stable");
    assert_eq!(a.mlm.to_bits(), b.mlm.to_bits(), "mlm not bit-stable");
    assert_eq!(a.sop.to_bits(), b.sop.to_bits(), "sop not bit-stable");
    for (name, g) in &a.grads.values {
        assert_eq!(g, &b.grads.values[name], "grad {name} not bit-stable");
    }
    for (d, (ha, hb)) in a.hidden.iter().zip(&b.hidden).enumerate() {
        assert_eq!(ha, hb, "hidden chunk {d} not bit-stable");
    }
}

/// Sequential simulation and threaded execution meter the SAME traffic —
/// byte-for-byte per collective kind (the accounting contract both
/// implementations of `comm::Collective` share).
#[test]
fn threaded_and_sequential_meters_agree() {
    for n in [2usize, 4] {
        let rt = runtime(n);
        let params = ParamStore::synthetic(rt.manifest());
        let batch = batch_for(&rt, 5);

        let seq_meter = Meter::new();
        let seq = SeqParEngine::new(&rt, Fabric::new(n, seq_meter.clone())).unwrap();
        seq.forward_backward(&params, &batch).unwrap();

        let thr_meter = Meter::new();
        let dist = DistRunner::new(&rt, thr_meter.clone()).unwrap();
        dist.forward_backward(&params, &batch).unwrap();

        for kind in [
            CommKind::RingP2p,
            CommKind::AllReduce,
            CommKind::AllGather,
            CommKind::Broadcast,
            CommKind::Pipeline,
        ] {
            assert_eq!(
                seq_meter.get(kind),
                thr_meter.get(kind),
                "n={n}: {kind:?} bytes differ (sequential {} vs threaded {})",
                seq_meter.get(kind),
                thr_meter.get(kind)
            );
        }
    }
}

/// The sparse patterns hold the same three-way equivalence: for
/// `--attn linformer:K` and `--attn block:W` at n ∈ {2, 4}, the threaded
/// runner, the sequential simulation, and a serial reference (the SAME
/// pattern on a ring of 1 — both patterns are token-level definitions, so
/// the mathematics is ring-size invariant) agree on loss, every gradient
/// (including the Linformer E_k/E_v projections), and the hidden chunks;
/// and sequential vs threaded meters agree byte-for-byte per collective.
#[test]
fn sparse_patterns_threaded_matches_sequential_and_serial() {
    for pattern in [AttnPattern::Linformer { k: 8 }, AttnPattern::Block { w: 8 }] {
        let (linformer_k, block_w) = pattern.native_knobs();
        // serial reference: ring of 1, same pattern, same weights (the
        // param inventory is ring-independent, so synthetic init agrees)
        let rt1 = Runtime::native(NativeConfig {
            ring: 1,
            linformer_k,
            block_w,
            ..NativeConfig::tiny()
        })
        .unwrap();
        let params1 = ParamStore::synthetic(rt1.manifest());
        let batch = batch_for(&rt1, 17);
        let serial = SeqParEngine::with_pattern(&rt1, Fabric::new(1, Meter::new()), pattern)
            .unwrap();
        let s = serial.forward_backward(&params1, &batch).unwrap();

        for n in [2usize, 4] {
            let tag = format!("attn={} n={n}", pattern.label());
            let rt = Runtime::native(NativeConfig {
                ring: n,
                linformer_k,
                block_w,
                ..NativeConfig::tiny()
            })
            .unwrap();
            let m = rt.manifest().clone();
            let params = ParamStore::synthetic(&m);
            for (name, t) in &params.values {
                assert_eq!(t, &params1.values[name], "{tag}: init param {name} differs");
            }

            let seq_meter = Meter::new();
            let seq =
                SeqParEngine::with_pattern(&rt, Fabric::new(n, seq_meter.clone()), pattern)
                    .unwrap();
            let q = seq.forward_backward(&params, &batch).unwrap();

            let thr_meter = Meter::new();
            let dist = DistRunner::with_pattern(&rt, thr_meter.clone(), pattern).unwrap();
            let t = dist.forward_backward(&params, &batch).unwrap();

            assert!(
                (t.loss - s.loss).abs() < TOL,
                "{tag}: threaded loss {} vs serial {}",
                t.loss,
                s.loss
            );
            assert!(
                (t.loss - q.loss).abs() < TOL,
                "{tag}: threaded loss {} vs sequential {}",
                t.loss,
                q.loss
            );
            assert_grads_close(&format!("{tag} threaded vs serial"), &t, &s, TOL);
            assert_grads_close(&format!("{tag} threaded vs sequential"), &t, &q, TOL);
            if linformer_k > 0 {
                // the new projection params actually receive gradient
                let ek = &t.grads.values["linformer_ek"];
                assert!(
                    ek.f32s().unwrap().iter().any(|&v| v != 0.0),
                    "{tag}: E_k gradient is all zero"
                );
            }

            // hidden chunks reassemble to the serial hidden states
            assert_eq!(t.hidden.len(), n);
            let lc = m.seq_len / n;
            let chunks3d: Vec<_> = t
                .hidden
                .iter()
                .map(|h| h.clone().reshaped(&[m.batch, lc, m.hidden]).unwrap())
                .collect();
            let refs: Vec<_> = chunks3d.iter().collect();
            let full = ops::concat_dim(&refs, 1)
                .unwrap()
                .reshaped(&[m.batch * m.seq_len, m.hidden])
                .unwrap();
            let dh = ops::max_abs_diff(&full, &s.hidden[0]).unwrap();
            assert!(dh < TOL, "{tag}: reassembled hidden vs serial Δ={dh}");

            // meter parity, byte-for-byte per collective kind
            for kind in [
                CommKind::RingP2p,
                CommKind::AllReduce,
                CommKind::AllGather,
                CommKind::Broadcast,
                CommKind::Pipeline,
            ] {
                assert_eq!(
                    seq_meter.get(kind),
                    thr_meter.get(kind),
                    "{tag}: {kind:?} bytes differ (sequential {} vs threaded {})",
                    seq_meter.get(kind),
                    thr_meter.get(kind)
                );
            }
        }
    }
}

/// Ulysses all-to-all SP holds the same three-way equivalence as the
/// ring: for n ∈ {2, 4} the threaded runner, the sequential simulation,
/// and the serial single-device engine agree on loss, every gradient and
/// the hidden chunks; the threaded run is bit-deterministic; sequential
/// vs threaded meters agree byte-for-byte per collective kind (including
/// the new all-to-all counter); and the measured all-to-all volume is
/// exactly the `8(n−1)`-chunk closed form with zero ring traffic.
#[test]
fn ulysses_threaded_matches_sequential_and_serial() {
    // serial reference: single device, plain dense attention — Ulysses
    // computes identical mathematics (full-sequence softmax per head)
    let rt1 = Runtime::native(NativeConfig { model: BERT_TINY_Z4, ring: 1, ..NativeConfig::tiny() })
        .unwrap();
    let params1 = ParamStore::synthetic(rt1.manifest());
    let batch = batch_for(&rt1, 29);
    let serial = TensorParEngine::new(&rt1, Fabric::new(1, Meter::new())).unwrap();
    let s = serial.forward_backward(&params1, &batch).unwrap();

    for n in [2usize, 4] {
        let tag = format!("ulysses n={n}");
        let rt = Runtime::native(NativeConfig {
            model: BERT_TINY_Z4,
            ring: n,
            ulysses: true,
            ..NativeConfig::tiny()
        })
        .unwrap();
        let m = rt.manifest().clone();
        let params = ParamStore::synthetic(&m);
        for (name, t) in &params.values {
            assert_eq!(t, &params1.values[name], "{tag}: init param {name} differs");
        }

        let seq_meter = Meter::new();
        let seq = SeqParEngine::with_strategy(
            &rt,
            Fabric::new(n, seq_meter.clone()),
            AttnPattern::Dense,
            SpStrategy::Ulysses,
        )
        .unwrap();
        let q = seq.forward_backward(&params, &batch).unwrap();

        let thr_meter = Meter::new();
        let dist =
            DistRunner::with_strategy(&rt, thr_meter.clone(), AttnPattern::Dense, SpStrategy::Ulysses)
                .unwrap();
        let t = dist.forward_backward(&params, &batch).unwrap();

        // the ring strategy at the same shape computes the same step
        let ring = SeqParEngine::new(&rt, Fabric::new(n, Meter::new())).unwrap();
        let r = ring.forward_backward(&params, &batch).unwrap();
        assert!(
            (t.loss - r.loss).abs() < TOL,
            "{tag}: ulysses loss {} vs ring loss {}",
            t.loss,
            r.loss
        );
        assert_grads_close(&format!("{tag} ulysses vs ring"), &t, &r, TOL);

        assert!(
            (t.loss - s.loss).abs() < TOL,
            "{tag}: threaded loss {} vs serial {}",
            t.loss,
            s.loss
        );
        assert!(
            (t.loss - q.loss).abs() < TOL,
            "{tag}: threaded loss {} vs sequential {}",
            t.loss,
            q.loss
        );
        assert_grads_close(&format!("{tag} threaded vs serial"), &t, &s, TOL);
        assert_grads_close(&format!("{tag} threaded vs sequential"), &t, &q, TOL);

        // hidden chunks reassemble to the serial hidden states
        assert_eq!(t.hidden.len(), n);
        let lc = m.seq_len / n;
        let chunks3d: Vec<_> = t
            .hidden
            .iter()
            .map(|h| h.clone().reshaped(&[m.batch, lc, m.hidden]).unwrap())
            .collect();
        let refs: Vec<_> = chunks3d.iter().collect();
        let full = ops::concat_dim(&refs, 1)
            .unwrap()
            .reshaped(&[m.batch * m.seq_len, m.hidden])
            .unwrap();
        let dh = ops::max_abs_diff(&full, &s.hidden[0]).unwrap();
        assert!(dh < TOL, "{tag}: reassembled hidden vs serial Δ={dh}");

        // bit-determinism across threaded runs
        let t2 = dist.forward_backward(&params, &batch).unwrap();
        assert_eq!(t.loss.to_bits(), t2.loss.to_bits(), "{tag}: loss not bit-stable");
        for (name, g) in &t.grads.values {
            assert_eq!(g, &t2.grads.values[name], "{tag}: grad {name} not bit-stable");
        }

        // comm profile: zero ring traffic, all-to-all on the closed form
        assert_eq!(seq_meter.get(CommKind::RingP2p), 0, "{tag}: ulysses rang the ring");
        let chunk_bytes = (m.batch * m.heads * lc * m.head_dim * 4) as u64;
        assert_eq!(
            seq_meter.get(CommKind::AllToAll),
            8 * (n as u64 - 1) * chunk_bytes * m.layers as u64,
            "{tag}: all-to-all bytes diverged from 8(n-1) chunks/layer"
        );
        // meter parity, byte-for-byte per collective kind
        for kind in [
            CommKind::RingP2p,
            CommKind::AllReduce,
            CommKind::AllGather,
            CommKind::AllToAll,
            CommKind::Broadcast,
            CommKind::Pipeline,
        ] {
            assert_eq!(
                seq_meter.get(kind),
                thr_meter.get(kind),
                "{tag}: {kind:?} bytes differ (sequential {} vs threaded {})",
                seq_meter.get(kind),
                thr_meter.get(kind)
            );
        }
    }
}

/// The Ulysses head-divisibility cap mirrors the Megatron §4.2 tp-over-
/// heads check: a ring that cannot shard whole heads is rejected up
/// front, as are a manifest lowered without the head-shard kernels and a
/// sparse pattern composed with the all-to-all strategy.
#[test]
fn ulysses_rejects_invalid_configs() {
    // bert-tiny has 2 heads: ring 4 cannot shard whole heads — rejected
    // at backend build with an error that names the cap
    let err = Runtime::native(NativeConfig { ulysses: true, ..NativeConfig::tiny() })
        .err()
        .expect("ulysses ring=4 over 2 heads must be rejected");
    let msg = format!("{err:#}");
    assert!(msg.contains("head count"), "unexpected rejection: {msg}");
    // a manifest lowered WITHOUT the ulysses kernels is refused at
    // engine build (sequential and threaded alike)
    let rt = Runtime::native(NativeConfig { ring: 2, ..NativeConfig::tiny() }).unwrap();
    assert!(SeqParEngine::with_strategy(
        &rt,
        Fabric::new(2, Meter::new()),
        AttnPattern::Dense,
        SpStrategy::Ulysses
    )
    .is_err());
    assert!(
        DistRunner::with_strategy(&rt, Meter::new(), AttnPattern::Dense, SpStrategy::Ulysses)
            .is_err()
    );
    // sparse patterns do not compose with the all-to-all strategy
    let rt = Runtime::native(NativeConfig {
        ring: 2,
        linformer_k: 8,
        ulysses: true,
        ..NativeConfig::tiny()
    })
    .unwrap();
    assert!(SeqParEngine::with_strategy(
        &rt,
        Fabric::new(2, Meter::new()),
        AttnPattern::Linformer { k: 8 },
        SpStrategy::Ulysses
    )
    .is_err());
}

fn phase_names(events: &[obs::Event], rank: usize) -> Vec<String> {
    events
        .iter()
        .filter(|e| e.rank == rank && matches!(e.kind, obs::EventKind::Phase { .. }))
        .map(|e| e.name())
        .collect()
}

fn kernel_totals(events: &[obs::Event]) -> (usize, u64) {
    let mut count = 0usize;
    let mut bytes = 0u64;
    for e in events {
        if let obs::EventKind::Kernel { bytes: b, .. } = &e.kind {
            count += 1;
            bytes += *b;
        }
    }
    (count, bytes)
}

fn comm_bytes(events: &[obs::Event], kind: CommKind) -> u64 {
    events
        .iter()
        .filter_map(|e| match &e.kind {
            obs::EventKind::Comm { kind: k, bytes, .. } if *k == kind => Some(*bytes),
            _ => None,
        })
        .sum()
}

/// Trace-shape parity: the threaded runner and the sequential simulation
/// record the same program.  One `seqpar_step` is one phase sequence
/// wherever it runs, so every threaded rank's ordered phase list must
/// equal the sequential rank-0 list; kernel event count/bytes and the
/// per-kind traced comm bytes must agree run-to-run (comm event COUNTS
/// legitimately differ — one group-total event on the sequential fabric
/// vs per-message events threaded — which is exactly what the
/// trace↔meter cross-check pins on each side).
#[test]
fn threaded_and_sequential_trace_shapes_agree() {
    let n = 4;
    let rt = runtime(n);
    let params = ParamStore::synthetic(rt.manifest());
    let batch = batch_for(&rt, 47);

    let seq_meter = Meter::new();
    let seq = SeqParEngine::new(&rt, Fabric::new(n, seq_meter.clone())).unwrap();
    let rec = obs::Recorder::start();
    seq.forward_backward(&params, &batch).unwrap();
    let seq_events = rec.finish();
    obs::cross_check(&seq_events, &seq_meter).unwrap();

    let thr_meter = Meter::new();
    let dist = DistRunner::new(&rt, thr_meter.clone()).unwrap();
    let rec = obs::Recorder::start();
    dist.forward_backward(&params, &batch).unwrap();
    let thr_events = rec.finish();
    obs::cross_check(&thr_events, &thr_meter).unwrap();

    // the sequential simulation records the whole program as rank 0
    let want = phase_names(&seq_events, 0);
    assert!(!want.is_empty(), "sequential run recorded no phases");
    for r in 0..n {
        assert_eq!(
            phase_names(&thr_events, r),
            want,
            "rank {r}: phase sequence diverged from the sequential program"
        );
    }

    // same math executed ⇒ same kernel-event count and traced bytes
    assert_eq!(
        kernel_totals(&seq_events),
        kernel_totals(&thr_events),
        "kernel (count, bytes) differ between sequential and threaded traces"
    );

    // per-kind comm bytes in the traces agree
    for kind in [
        CommKind::RingP2p,
        CommKind::AllReduce,
        CommKind::AllGather,
        CommKind::AllToAll,
        CommKind::Broadcast,
        CommKind::Pipeline,
    ] {
        assert_eq!(
            comm_bytes(&seq_events, kind),
            comm_bytes(&thr_events, kind),
            "{kind:?}: traced bytes differ between sequential and threaded"
        );
    }
}

/// Memory parity: sequential simulation and threaded execution charge
/// the SAME per-rank memory.  One step of each runs under its own
/// `obs::mem` session; every (lane, category) high-water mark must
/// match byte-for-byte — across both SP strategies and the sparse
/// patterns — because the per-rank tensor lifetimes are decided by the
/// dataflow, not by where the ranks run.
#[test]
fn threaded_and_sequential_memory_peaks_agree() {
    for n in [2usize, 4] {
        let cases = [
            (
                "dense",
                NativeConfig { ring: n, ..NativeConfig::tiny() },
                AttnPattern::Dense,
                SpStrategy::Ring,
            ),
            (
                "linformer:8",
                NativeConfig { ring: n, linformer_k: 8, ..NativeConfig::tiny() },
                AttnPattern::Linformer { k: 8 },
                SpStrategy::Ring,
            ),
            (
                "block:8",
                NativeConfig { ring: n, block_w: 8, ..NativeConfig::tiny() },
                AttnPattern::Block { w: 8 },
                SpStrategy::Ring,
            ),
            (
                "ulysses",
                NativeConfig { model: BERT_TINY_Z4, ring: n, ulysses: true, ..NativeConfig::tiny() },
                AttnPattern::Dense,
                SpStrategy::Ulysses,
            ),
        ];
        for (label, cfg, pattern, sp) in cases {
            let tag = format!("{label} n={n}");
            let rt = Runtime::native(cfg).unwrap();
            let params = ParamStore::synthetic(rt.manifest());
            let batch = batch_for(&rt, 53);

            let seq = SeqParEngine::with_strategy(&rt, Fabric::new(n, Meter::new()), pattern, sp)
                .unwrap();
            let ses = obs::mem::MemSession::start();
            seq.forward_backward(&params, &batch).unwrap();
            let a = ses.finish();

            let dist = DistRunner::with_strategy(&rt, Meter::new(), pattern, sp).unwrap();
            let ses = obs::mem::MemSession::start();
            dist.forward_backward(&params, &batch).unwrap();
            let b = ses.finish();

            assert_eq!(a.lanes.len(), n, "{tag}: sequential run charged the wrong lane count");
            assert_eq!(b.lanes.len(), n, "{tag}: threaded run charged the wrong lane count");
            for (la, lb) in a.lanes.iter().zip(&b.lanes) {
                assert_eq!(la.lane, lb.lane, "{tag}: lane sets differ");
                assert_eq!(
                    la.peak, lb.peak,
                    "{tag}: lane {} per-category peaks differ (sequential vs threaded)",
                    la.lane
                );
            }
        }
    }
}

/// Comm/compute overlap (`--overlap`, the double-buffered ring) is
/// correctness-preserving: for n ∈ {2, 4, 8} the overlapped threaded
/// runner computes bit-identical results to the blocking threaded
/// runner, matches the sequential simulation and the serial engine
/// within tolerance, stays bit-deterministic run-to-run, and meters
/// byte-identical traffic per collective kind.  Posting a shift early
/// moves only WHEN the bytes travel, never what is computed.
#[test]
fn overlap_threaded_matches_sequential_and_serial() {
    for n in [2usize, 4, 8] {
        let rt = runtime(n);
        let params = ParamStore::synthetic(rt.manifest());
        let batch = batch_for(&rt, 21);

        let serial = TensorParEngine::new(&rt, Fabric::new(1, Meter::new())).unwrap();
        let s = serial.forward_backward(&params, &batch).unwrap();

        let seq_meter = Meter::new();
        let seq = SeqParEngine::new(&rt, Fabric::new(n, seq_meter.clone()))
            .unwrap()
            .overlap(true);
        let q = seq.forward_backward(&params, &batch).unwrap();

        let thr_meter = Meter::new();
        let dist = DistRunner::new(&rt, thr_meter.clone()).unwrap().overlap(true);
        let t = dist.forward_backward(&params, &batch).unwrap();

        // the overlapped schedule computes on the same held chunks in the
        // same order as the blocking one — identical bits, not just close
        let blocking = DistRunner::new(&rt, Meter::new()).unwrap();
        let r = blocking.forward_backward(&params, &batch).unwrap();
        assert_eq!(t.loss.to_bits(), r.loss.to_bits(), "n={n}: overlap moved the loss bits");
        for (name, g) in &t.grads.values {
            assert_eq!(g, &r.grads.values[name], "n={n}: overlap moved grad {name}");
        }
        for (d, (ho, hb)) in t.hidden.iter().zip(&r.hidden).enumerate() {
            assert_eq!(ho, hb, "n={n}: overlap moved hidden chunk {d}");
        }

        // three-way equivalence, same as the blocking suite
        assert!(
            (t.loss - s.loss).abs() < TOL,
            "n={n}: overlapped loss {} vs serial {}",
            t.loss,
            s.loss
        );
        assert!(
            (t.loss - q.loss).abs() < TOL,
            "n={n}: overlapped threaded loss {} vs sequential {}",
            t.loss,
            q.loss
        );
        assert_grads_close(&format!("n={n} overlap threaded vs serial"), &t, &s, TOL);
        assert_grads_close(&format!("n={n} overlap threaded vs sequential"), &t, &q, TOL);

        // bit-determinism holds with shifts in flight during compute
        let t2 = dist.forward_backward(&params, &batch).unwrap();
        assert_eq!(t.loss.to_bits(), t2.loss.to_bits(), "n={n}: overlap loss not bit-stable");
        for (name, g) in &t.grads.values {
            assert_eq!(g, &t2.grads.values[name], "n={n}: overlap grad {name} not bit-stable");
        }

        // meter parity: the overlapped sequential simulation and the
        // overlapped threaded run record byte-identical traffic
        for kind in [
            CommKind::RingP2p,
            CommKind::AllReduce,
            CommKind::AllGather,
            CommKind::AllToAll,
            CommKind::Broadcast,
            CommKind::Pipeline,
        ] {
            assert_eq!(
                seq_meter.get(kind),
                thr_meter.get(kind),
                "n={n}: {kind:?} bytes differ with overlap on (sequential {} vs threaded {})",
                seq_meter.get(kind),
                thr_meter.get(kind)
            );
        }
    }
}

/// Memory parity under overlap: the in-flight double-buffer chunk is
/// charged to the same `ring_buf` lane account by the sequential
/// simulation and the threaded runner, so every (lane, category)
/// high-water mark matches byte-for-byte — the overlapped analogue of
/// `threaded_and_sequential_memory_peaks_agree` (the 2→3-chunk closed
/// form itself is pinned in rust/tests/mem_validation.rs).
#[test]
fn overlap_memory_peaks_agree() {
    for n in [2usize, 4] {
        let rt = runtime(n);
        let params = ParamStore::synthetic(rt.manifest());
        let batch = batch_for(&rt, 53);

        let seq = SeqParEngine::new(&rt, Fabric::new(n, Meter::new()))
            .unwrap()
            .overlap(true);
        let ses = obs::mem::MemSession::start();
        seq.forward_backward(&params, &batch).unwrap();
        let a = ses.finish();

        let dist = DistRunner::new(&rt, Meter::new()).unwrap().overlap(true);
        let ses = obs::mem::MemSession::start();
        dist.forward_backward(&params, &batch).unwrap();
        let b = ses.finish();

        assert_eq!(a.lanes.len(), n, "n={n}: sequential overlap charged the wrong lane count");
        assert_eq!(b.lanes.len(), n, "n={n}: threaded overlap charged the wrong lane count");
        for (la, lb) in a.lanes.iter().zip(&b.lanes) {
            assert_eq!(la.lane, lb.lane, "n={n}: lane sets differ");
            assert_eq!(
                la.peak, lb.peak,
                "n={n}: lane {} per-category peaks differ under overlap",
                la.lane
            );
        }
    }
}

/// A rank panic mid-step must not hang the ring.  The dying rank's
/// channel endpoints drop; every peer blocked on a recv from it gets a
/// contextful "peer disconnected" error and unwinds; the runner joins
/// ALL threads and reports the panicked rank by number as the root
/// cause — never a peer left blocked forever on a recv with nobody
/// alive to send.
#[test]
fn rank_panic_is_reported_not_hung() {
    for overlap in [false, true] {
        let n = 4;
        let rt = runtime(n);
        let params = ParamStore::synthetic(rt.manifest());
        let batch = batch_for(&rt, 61);

        let mut dist = DistRunner::new(&rt, Meter::new()).unwrap().overlap(overlap);
        dist.inject_fault(2);
        let err = dist
            .forward_backward(&params, &batch)
            .err()
            .expect("a dead rank must fail the step, not hang it");
        let msg = format!("{err:#}");
        assert!(
            msg.contains("rank 2"),
            "overlap={overlap}: error must name the dead rank: {msg}"
        );
        assert!(
            msg.contains("panicked"),
            "overlap={overlap}: error must say the rank panicked: {msg}"
        );
    }
}

/// The failure contract holds under every step schedule, not just the
/// dense ring: Linformer (all-reduce mid-flight), block-sparse (rings
/// with skipped hops), and Ulysses (all-to-alls mid-flight), each with
/// overlap on and off.  In every case the peers of the dead rank see the
/// disconnect, the join reports rank 2 by number, and the error carries
/// the typed `RankFailure` the elastic driver downcasts for.
#[test]
fn rank_panic_is_reported_under_every_schedule() {
    let n = 4;
    let cases: [(AttnPattern, SpStrategy); 3] = [
        (AttnPattern::Linformer { k: 8 }, SpStrategy::Ring),
        (AttnPattern::Block { w: 8 }, SpStrategy::Ring),
        (AttnPattern::Dense, SpStrategy::Ulysses),
    ];
    for (pattern, sp) in cases {
        for overlap in [false, true] {
            let tag = format!("attn={} sp={} overlap={overlap}", pattern.label(), sp.label());
            let (linformer_k, block_w) = pattern.native_knobs();
            // ulysses shards whole heads: the 4-head tiny variant admits n=4
            let rt = Runtime::native(NativeConfig {
                model: BERT_TINY_Z4,
                ring: n,
                linformer_k,
                block_w,
                ulysses: !sp.is_ring(),
                ..NativeConfig::tiny()
            })
            .unwrap();
            let params = ParamStore::synthetic(rt.manifest());
            let batch = batch_for(&rt, 67);
            let mut dist = DistRunner::with_strategy(&rt, Meter::new(), pattern, sp)
                .unwrap()
                .overlap(overlap);
            dist.inject_fault(2);
            let err = dist
                .forward_backward(&params, &batch)
                .err()
                .unwrap_or_else(|| panic!("{tag}: a dead rank must fail the step, not hang it"));
            let msg = format!("{err:#}");
            assert!(msg.contains("rank 2"), "{tag}: error must name the dead rank: {msg}");
            assert!(msg.contains("panicked"), "{tag}: error must say it panicked: {msg}");
            let failure = err
                .downcast_ref::<RankFailure>()
                .unwrap_or_else(|| panic!("{tag}: error must downcast to RankFailure"));
            assert_eq!((failure.rank, failure.world, failure.on_mesh), (2, n, false), "{tag}");
        }
    }
}

/// The runner refuses gracefully when the manifest ring size does not
/// divide the sequence — same contract as the sequential engine.
#[test]
fn runner_validates_shapes() {
    // valid: the manifest ring is reported as the rank count
    let rt = runtime(4);
    let d = DistRunner::new(&rt, Meter::new()).unwrap();
    assert_eq!(d.group_size(), 4);
    assert_eq!(d.name(), "seq-par-threaded");
    // invalid: seq_len 32 with ring 5 must be refused up front by the
    // runner (and by the sequential engine) even if the backend itself
    // can synthesize a manifest for that shape
    if let Ok(bad) = Runtime::native(NativeConfig { ring: 5, ..NativeConfig::tiny() }) {
        assert!(DistRunner::new(&bad, Meter::new()).is_err());
        assert!(SeqParEngine::new(&bad, Fabric::new(5, Meter::new())).is_err());
    }
}
