//! Integration: failure injection on the executor/manifest layer.
//!
//! A coordinator that silently mis-executes is worse than one that
//! crashes: every orchestration error (wrong shape, unknown artifact,
//! truncated manifest) must fail loudly and NAME the artifact.  The
//! native backend enforces the same manifest contract as the PJRT one,
//! so these run with zero artifacts.

use seqpar::backend::native::NativeConfig;
use seqpar::runtime::{Manifest, Runtime};
use seqpar::tensor::Tensor;

fn runtime() -> Runtime {
    Runtime::native(NativeConfig::tiny()).unwrap()
}

/// Zero-filled inputs matching an artifact's spec.
fn inputs_for(rt: &Runtime, name: &str) -> Vec<Tensor> {
    rt.manifest().artifacts[name]
        .inputs
        .iter()
        .map(|io| match io.dtype {
            seqpar::tensor::DType::F32 => Tensor::zeros(&io.dims),
            seqpar::tensor::DType::I32 => {
                Tensor::from_i32(&io.dims, vec![0; io.dims.iter().product()]).unwrap()
            }
        })
        .collect()
}

#[test]
fn wrong_shape_errors_with_artifact_name() {
    let rt = runtime();
    // pick any artifact and feed it a wrong-shaped first input
    let name = rt.manifest().artifacts.keys().next().unwrap().clone();
    let mut inputs = inputs_for(&rt, &name);
    inputs[0] = Tensor::zeros(&[3, 5, 7]); // wrong
    let refs: Vec<&Tensor> = inputs.iter().collect();
    let err = rt.call(&name, &refs).unwrap_err().to_string();
    assert!(
        err.contains(name.split("__").next().unwrap()),
        "error should name the artifact: {err}"
    );
}

#[test]
fn unknown_artifact_is_rejected() {
    let rt = runtime();
    let err = rt.call("nonexistent__1x1", &[]).unwrap_err().to_string();
    assert!(err.contains("not in manifest"), "{err}");
}

#[test]
fn wrong_arity_is_rejected_before_execution() {
    let rt = runtime();
    let name = rt.manifest().artifacts.keys().next().unwrap().clone();
    let err = rt.call(&name, &[]).unwrap_err().to_string();
    assert!(err.contains("inputs"), "{err}");
}

#[test]
fn wrong_dtype_is_rejected() {
    let rt = runtime();
    // embed_fwd's first input must be i32 ids; hand it f32 of the right shape
    let name = rt
        .manifest()
        .artifacts
        .keys()
        .find(|n| n.starts_with("embed_fwd__"))
        .unwrap()
        .clone();
    let mut inputs = inputs_for(&rt, &name);
    inputs[0] = Tensor::zeros(&rt.manifest().artifacts[&name].inputs[0].dims.clone());
    let refs: Vec<&Tensor> = inputs.iter().collect();
    let err = rt.call(&name, &refs).unwrap_err().to_string();
    assert!(err.contains("embed_fwd"), "{err}");
}

#[test]
fn every_artifact_executes_on_valid_zero_inputs() {
    // The native backend's output shapes must match its own manifest for
    // every registered artifact — dispatch, compute, and re-validate.
    let rt = runtime();
    let names: Vec<String> = rt.manifest().artifacts.keys().cloned().collect();
    for name in names {
        let inputs = inputs_for(&rt, &name);
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let out = rt
            .call(&name, &refs)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        let spec = &rt.manifest().artifacts[&name];
        assert_eq!(out.len(), spec.outputs.len(), "{name}: output arity");
        for (t, io) in out.iter().zip(&spec.outputs) {
            assert_eq!(t.shape, io.dims, "{name}: output shape");
        }
    }
}

#[test]
fn manifest_rejects_truncation() {
    // a structurally-valid but incomplete document must fail to parse
    assert!(Manifest::parse("{\"model\": \"x\"}").is_err());
    // and a syntactically-truncated one
    assert!(Manifest::parse("{\"model\": \"x\", \"batch\": 2, \"art").is_err());
}

#[test]
fn open_without_feature_or_artifacts_fails_helpfully() {
    // Without backend-xla, Runtime::open must explain itself; with it,
    // opening a missing directory must fail on the manifest.
    let err = Runtime::open(std::path::Path::new("/definitely/not/here"))
        .unwrap_err()
        .to_string();
    assert!(
        err.contains("backend-xla") || err.contains("manifest"),
        "unhelpful error: {err}"
    );
}

/// Artifact-backed error-path checks (PJRT backend, lazy compile).
#[cfg(feature = "backend-xla")]
mod xla_artifacts {
    use super::*;
    use std::path::PathBuf;

    fn artifacts() -> Option<PathBuf> {
        let dir = PathBuf::from("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn missing_artifact_file_fails_at_first_use_not_open() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        // copy manifest into a temp dir WITHOUT the hlo files: open
        // succeeds (lazy compile), first call fails cleanly.
        let tmp = std::env::temp_dir().join("seqpar_missing_artifacts");
        let _ = std::fs::remove_dir_all(&tmp);
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::copy(dir.join("manifest.json"), tmp.join("manifest.json")).unwrap();
        let rt = Runtime::open(&tmp).unwrap();
        let name = rt.manifest().artifacts.keys().next().unwrap().clone();
        let inputs = inputs_for(&rt, &name);
        let refs: Vec<&Tensor> = inputs.iter().collect();
        assert!(rt.call(&name, &refs).is_err());
    }
}
