//! Integration: failure injection on the runtime/manifest layer.
//!
//! A coordinator that silently mis-executes is worse than one that
//! crashes: every orchestration error (wrong shape, unknown artifact,
//! truncated manifest) must fail loudly and NAME the artifact.

use std::path::PathBuf;

use seqpar::runtime::{Manifest, Runtime};
use seqpar::tensor::Tensor;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn wrong_shape_errors_with_artifact_name() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = Runtime::open(&dir).unwrap();
    // pick any artifact and feed it a wrong-shaped first input
    let (name, spec) = rt.manifest.artifacts.iter().next().unwrap();
    let mut inputs: Vec<Tensor> = spec
        .inputs
        .iter()
        .map(|io| match io.dtype {
            seqpar::tensor::DType::F32 => Tensor::zeros(&io.dims),
            seqpar::tensor::DType::I32 => {
                Tensor::from_i32(&io.dims, vec![0; io.dims.iter().product()]).unwrap()
            }
        })
        .collect();
    inputs[0] = Tensor::zeros(&[3, 5, 7]); // wrong
    let refs: Vec<&Tensor> = inputs.iter().collect();
    let err = rt.call(name, &refs).unwrap_err().to_string();
    assert!(err.contains(name.split("__").next().unwrap()), "error should name the artifact: {err}");
}

#[test]
fn unknown_artifact_suggests_rebuilding() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = Runtime::open(&dir).unwrap();
    let err = rt.call("nonexistent__1x1", &[]).unwrap_err().to_string();
    assert!(err.contains("not in manifest"), "{err}");
}

#[test]
fn wrong_arity_is_rejected_before_execution() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let rt = Runtime::open(&dir).unwrap();
    let (name, _) = rt.manifest.artifacts.iter().next().unwrap();
    let err = rt.call(name, &[]).unwrap_err().to_string();
    assert!(err.contains("inputs"), "{err}");
}

#[test]
fn manifest_rejects_truncation() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
    let truncated = &text[..text.len() / 2];
    assert!(Manifest::parse(truncated).is_err());
    // and a structurally-valid but incomplete document
    assert!(Manifest::parse("{\"model\": \"x\"}").is_err());
}

#[test]
fn missing_artifact_file_fails_at_first_use_not_open() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    // copy manifest into a temp dir WITHOUT the hlo files: open succeeds
    // (lazy compile), first call fails cleanly.
    let tmp = std::env::temp_dir().join("seqpar_missing_artifacts");
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp).unwrap();
    std::fs::copy(dir.join("manifest.json"), tmp.join("manifest.json")).unwrap();
    let rt = Runtime::open(&tmp).unwrap();
    let (name, spec) = rt.manifest.artifacts.iter().next().unwrap();
    let inputs: Vec<Tensor> = spec
        .inputs
        .iter()
        .map(|io| match io.dtype {
            seqpar::tensor::DType::F32 => Tensor::zeros(&io.dims),
            seqpar::tensor::DType::I32 => {
                Tensor::from_i32(&io.dims, vec![0; io.dims.iter().product()]).unwrap()
            }
        })
        .collect();
    let refs: Vec<&Tensor> = inputs.iter().collect();
    assert!(rt.call(name, &refs).is_err());
}
