//! Integration: failure injection on the executor/manifest layer.
//!
//! A coordinator that silently mis-executes is worse than one that
//! crashes: every orchestration error (wrong shape, unknown artifact,
//! truncated manifest) must fail loudly and NAME the artifact.  The
//! native backend enforces the same manifest contract as the PJRT one,
//! so these run with zero artifacts.

use seqpar::backend::native::NativeConfig;
use seqpar::runtime::{Manifest, Runtime};
use seqpar::tensor::Tensor;

fn runtime() -> Runtime {
    Runtime::native(NativeConfig::tiny()).unwrap()
}

/// Zero-filled inputs matching an artifact's spec.
fn inputs_for(rt: &Runtime, name: &str) -> Vec<Tensor> {
    rt.manifest().artifacts[name]
        .inputs
        .iter()
        .map(|io| match io.dtype {
            seqpar::tensor::DType::F32 => Tensor::zeros(&io.dims),
            seqpar::tensor::DType::I32 => {
                Tensor::from_i32(&io.dims, vec![0; io.dims.iter().product()]).unwrap()
            }
        })
        .collect()
}

#[test]
fn wrong_shape_errors_with_artifact_name() {
    let rt = runtime();
    // pick any artifact and feed it a wrong-shaped first input
    let name = rt.manifest().artifacts.keys().next().unwrap().clone();
    let mut inputs = inputs_for(&rt, &name);
    inputs[0] = Tensor::zeros(&[3, 5, 7]); // wrong
    let refs: Vec<&Tensor> = inputs.iter().collect();
    let err = rt.call(&name, &refs).unwrap_err().to_string();
    assert!(
        err.contains(name.split("__").next().unwrap()),
        "error should name the artifact: {err}"
    );
}

#[test]
fn unknown_artifact_is_rejected() {
    let rt = runtime();
    let err = rt.call("nonexistent__1x1", &[]).unwrap_err().to_string();
    assert!(err.contains("not in manifest"), "{err}");
}

#[test]
fn wrong_arity_is_rejected_before_execution() {
    let rt = runtime();
    let name = rt.manifest().artifacts.keys().next().unwrap().clone();
    let err = rt.call(&name, &[]).unwrap_err().to_string();
    assert!(err.contains("inputs"), "{err}");
}

#[test]
fn wrong_dtype_is_rejected() {
    let rt = runtime();
    // embed_fwd's first input must be i32 ids; hand it f32 of the right shape
    let name = rt
        .manifest()
        .artifacts
        .keys()
        .find(|n| n.starts_with("embed_fwd__"))
        .unwrap()
        .clone();
    let mut inputs = inputs_for(&rt, &name);
    inputs[0] = Tensor::zeros(&rt.manifest().artifacts[&name].inputs[0].dims.clone());
    let refs: Vec<&Tensor> = inputs.iter().collect();
    let err = rt.call(&name, &refs).unwrap_err().to_string();
    assert!(err.contains("embed_fwd"), "{err}");
}

#[test]
fn every_artifact_executes_on_valid_zero_inputs() {
    // The native backend's output shapes must match its own manifest for
    // every registered artifact — dispatch, compute, and re-validate.
    let rt = runtime();
    let names: Vec<String> = rt.manifest().artifacts.keys().cloned().collect();
    for name in names {
        let inputs = inputs_for(&rt, &name);
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let out = rt
            .call(&name, &refs)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        let spec = &rt.manifest().artifacts[&name];
        assert_eq!(out.len(), spec.outputs.len(), "{name}: output arity");
        for (t, io) in out.iter().zip(&spec.outputs) {
            assert_eq!(t.shape, io.dims, "{name}: output shape");
        }
    }
}

#[test]
fn manifest_rejects_truncation() {
    // a structurally-valid but incomplete document must fail to parse
    assert!(Manifest::parse("{\"model\": \"x\"}").is_err());
    // and a syntactically-truncated one
    assert!(Manifest::parse("{\"model\": \"x\", \"batch\": 2, \"art").is_err());
}

#[test]
fn malformed_manifest_errors_name_file_key_and_type() {
    // regression for the unwrap()-era parser: every malformation below
    // used to either panic or silently coerce.  The error chain must say
    // WHERE (artifact/param + field) and WHAT (expected vs actual type).
    let base = r#"{
        "model": "bert-tiny", "batch": 2, "seq_len": 64, "ring": 4, "tp": 2,
        "linformer_k": 0, "hidden": 128, "heads": 2, "head_dim": 64,
        "ffn": 512, "layers": 2, "vocab": 1024, "seed": 0,
        "artifacts": {
            "add__32x128_32x128": {
                "file": "add.hlo.txt",
                "inputs": [{"dims": [32, 128], "dtype": "f32"}],
                "outputs": [{"dims": [32, 128], "dtype": "f32"}]
            }
        },
        "params": [{"name": "tok_emb", "dims": [1024, 128],
                    "file": "params/tok_emb.tensor"}],
        "goldens": {}
    }"#;
    assert!(Manifest::parse(base).is_ok(), "the base document must be valid");

    // (mutation, fragments the error chain must contain)
    let cases: Vec<(String, Vec<&str>)> = vec![
        // negative dim: silently became a huge usize under `f as usize`
        (
            base.replacen("[32, 128]", "[-32, 128]", 1),
            vec!["add__32x128_32x128", "dims[0]"],
        ),
        // fractional scalar: silently truncated
        (base.replace("\"ring\": 4,", "\"ring\": 4.25,"), vec!["ring", "whole number"]),
        // numeric scalar of the wrong JSON type
        (base.replace("\"batch\": 2,", "\"batch\": \"2\","), vec!["batch", "got a string"]),
        // non-string param name: silently became ""
        (base.replace("\"name\": \"tok_emb\"", "\"name\": 7"), vec!["params[0]", "name"]),
        // non-string artifact file path
        (base.replace("\"file\": \"add.hlo.txt\"", "\"file\": null"), vec!["add__", "file"]),
        // artifact io spec with a bogus dtype
        (base.replacen("\"dtype\": \"f32\"", "\"dtype\": \"f16\"", 1), vec!["dtype", "f16"]),
    ];
    for (doc, want) in cases {
        let err = Manifest::parse(&doc).expect_err("mutation should be rejected");
        let chain = format!("{err:#}");
        for frag in want {
            assert!(chain.contains(frag), "error {chain:?} should mention {frag:?}");
        }
    }
}

#[test]
fn open_without_feature_or_artifacts_fails_helpfully() {
    // Without backend-xla, Runtime::open must explain itself; with it,
    // opening a missing directory must fail on the manifest.
    let err = Runtime::open(std::path::Path::new("/definitely/not/here"))
        .unwrap_err()
        .to_string();
    assert!(
        err.contains("backend-xla") || err.contains("manifest"),
        "unhelpful error: {err}"
    );
}

/// Artifact-backed error-path checks (PJRT backend, lazy compile).
#[cfg(feature = "backend-xla")]
mod xla_artifacts {
    use super::*;
    use std::path::PathBuf;

    fn artifacts() -> Option<PathBuf> {
        let dir = PathBuf::from("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn missing_artifact_file_fails_at_first_use_not_open() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        // copy manifest into a temp dir WITHOUT the hlo files: open
        // succeeds (lazy compile), first call fails cleanly.
        let tmp = std::env::temp_dir().join("seqpar_missing_artifacts");
        let _ = std::fs::remove_dir_all(&tmp);
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::copy(dir.join("manifest.json"), tmp.join("manifest.json")).unwrap();
        let rt = Runtime::open(&tmp).unwrap();
        let name = rt.manifest().artifacts.keys().next().unwrap().clone();
        let inputs = inputs_for(&rt, &name);
        let refs: Vec<&Tensor> = inputs.iter().collect();
        assert!(rt.call(&name, &refs).is_err());
    }
}
