//! Integration: MEASURED per-rank memory peaks equal the simulator's
//! closed forms, category by category, byte for byte.
//!
//! One training step (forward + backward + Adam) of the sequential
//! `SeqParEngine` runs under an `obs::mem` accounting session; every
//! rank's high-water mark per category must EQUAL
//! `simulator::memory::sp_expect` — the memory analogue of the
//! comm-byte closed forms the meter tests pin.  Covered surface:
//!
//! * `--sp ring`  × dense / linformer:K / block:W, n ∈ {1, 2, 4};
//! * `--sp ulysses` × dense, n ∈ {1, 2, 4} (bert-tiny-z4 — Ulysses
//!   shards whole heads, so n must divide the head count);
//! * `--overlap` × dense ring and ulysses, n ∈ {1, 2, 4} — the
//!   double-buffered ring's grown `ring_buf` form (2 → 3 chunk slots).
//!
//! `ring_buf` is asserted only where `sp_expect` pins it (dense ring:
//! exactly two in-flight chunk slot sets; Ulysses / Linformer: zero);
//! block-sparse ring residency is schedule-dependent and stays
//! report-only.  `pipe_stash` must be zero on these flat engines, and
//! no lane may hold live bytes after the session — every charge is
//! RAII-scoped to the tensors it covers.

use seqpar::attn::AttnPattern;
use seqpar::backend::native::NativeConfig;
use seqpar::comm::{Fabric, Meter};
use seqpar::model::params::ParamStore;
use seqpar::model::BERT_TINY_Z4;
use seqpar::obs::mem::{Category, MemReport, MemSession, NCAT};
use seqpar::parallel::sequence::{SeqParEngine, SpStrategy};
use seqpar::runtime::Runtime;
use seqpar::simulator::memory::{sp_expect, sp_expect_overlap};
use seqpar::simulator::{RunShape, Strategy};
use seqpar::train::data::{Corpus, CorpusConfig};
use seqpar::train::trainer::{TrainConfig, Trainer};

/// One accounted training step on the sequential SP engine; returns the
/// finished session report plus the run shape the closed forms take.
fn measure(cfg: NativeConfig, pattern: AttnPattern, sp: SpStrategy) -> (MemReport, RunShape) {
    measure_overlap(cfg, pattern, sp, false)
}

/// [`measure`] with the comm/compute-overlap knob (`--overlap`).
fn measure_overlap(
    cfg: NativeConfig,
    pattern: AttnPattern,
    sp: SpStrategy,
    overlap: bool,
) -> (MemReport, RunShape) {
    let n = cfg.ring;
    let rt = Runtime::native(cfg).unwrap();
    let m = rt.manifest().clone();
    let mut params = ParamStore::synthetic(&m);
    let mut corpus = Corpus::new(CorpusConfig::new(m.vocab, m.seq_len, m.batch), 11);
    let engine = SeqParEngine::with_strategy(&rt, Fabric::new(n, Meter::new()), pattern, sp)
        .unwrap()
        .overlap(overlap);
    let shape = RunShape::new(seqpar::model::by_name(&m.model).unwrap(), m.batch, m.seq_len);

    let ses = MemSession::start();
    let mut tr = Trainer::new(
        &engine,
        &params,
        TrainConfig { steps: 1, warmup: 0, peak_lr: 1e-3, log_every: 1 },
    );
    tr.run(&mut params, || corpus.next_batch(), true).unwrap();
    (ses.finish(), shape)
}

/// Measured peaks == closed forms for every rank, category by category.
fn assert_expected(
    tag: &str,
    report: &MemReport,
    shape: &RunShape,
    strategy: Strategy,
    pattern: AttnPattern,
) {
    assert_expected_overlap(tag, report, shape, strategy, pattern, false)
}

/// [`assert_expected`] against the overlap-aware closed forms.
fn assert_expected_overlap(
    tag: &str,
    report: &MemReport,
    shape: &RunShape,
    strategy: Strategy,
    pattern: AttnPattern,
    overlap: bool,
) {
    let n = strategy.n();
    assert_eq!(
        report.lanes.len(),
        n,
        "{tag}: expected {n} charged lanes, got {:?}",
        report.lanes.iter().map(|l| l.lane).collect::<Vec<_>>()
    );
    for d in 0..n {
        let exp = if overlap {
            sp_expect_overlap(shape, strategy, pattern, d, true)
        } else {
            sp_expect(shape, strategy, pattern, d)
        };
        let lane = report
            .lane(d)
            .unwrap_or_else(|| panic!("{tag}: rank {d} charged nothing"));
        assert_eq!(lane.peak(Category::Params), exp.params, "{tag}: rank {d} params");
        assert_eq!(lane.peak(Category::Grads), exp.grads, "{tag}: rank {d} grads");
        assert_eq!(lane.peak(Category::Optimizer), exp.optimizer, "{tag}: rank {d} optimizer");
        assert_eq!(lane.peak(Category::Activation), exp.activation, "{tag}: rank {d} activation");
        assert_eq!(lane.peak(Category::AttnStash), exp.attn_stash, "{tag}: rank {d} attn_stash");
        if let Some(rb) = exp.ring_buf {
            assert_eq!(lane.peak(Category::RingBuf), rb, "{tag}: rank {d} ring_buf");
        }
        assert_eq!(lane.peak(Category::PipeStash), 0, "{tag}: rank {d} pipe_stash (flat engine)");
        assert_eq!(lane.live, [0u64; NCAT], "{tag}: rank {d} held live bytes past the session");
    }
    // churn is report-only, but a real step must have materialized tensors
    assert!(report.churn_tensors > 0, "{tag}: no allocation churn recorded");
}

#[test]
fn ring_dense_peaks_match_closed_forms() {
    for n in [1usize, 2, 4] {
        let (report, shape) =
            measure(NativeConfig { ring: n, ..NativeConfig::tiny() }, AttnPattern::Dense, SpStrategy::Ring);
        assert_expected(
            &format!("ring dense n={n}"),
            &report,
            &shape,
            Strategy::Sequence { n },
            AttnPattern::Dense,
        );
    }
}

#[test]
fn ring_linformer_peaks_match_closed_forms() {
    let k = 8usize;
    for n in [1usize, 2, 4] {
        let (report, shape) = measure(
            NativeConfig { ring: n, linformer_k: k, ..NativeConfig::tiny() },
            AttnPattern::Linformer { k },
            SpStrategy::Ring,
        );
        assert_expected(
            &format!("ring linformer:{k} n={n}"),
            &report,
            &shape,
            Strategy::Sequence { n },
            AttnPattern::Linformer { k },
        );
    }
}

#[test]
fn ring_block_peaks_match_closed_forms() {
    let w = 8usize;
    for n in [1usize, 2, 4] {
        let (report, shape) = measure(
            NativeConfig { ring: n, block_w: w, ..NativeConfig::tiny() },
            AttnPattern::Block { w },
            SpStrategy::Ring,
        );
        assert_expected(
            &format!("ring block:{w} n={n}"),
            &report,
            &shape,
            Strategy::Sequence { n },
            AttnPattern::Block { w },
        );
    }
}

/// `--overlap` (double-buffered ring): the dense ring's measured
/// `ring_buf` peak grows by exactly ONE in-flight chunk per rank —
/// 2 → 3 chunk slots, `sp_expect_overlap`'s grown closed form — while
/// every other category stays on the blocking form byte-for-byte.  A
/// ring of 1 has no hop to post, so its peak stays at the blocking
/// form; Ulysses never touches the ring buffers with or without the
/// knob.
#[test]
fn overlap_peaks_match_grown_closed_forms() {
    for n in [1usize, 2, 4] {
        let (report, shape) = measure_overlap(
            NativeConfig { ring: n, ..NativeConfig::tiny() },
            AttnPattern::Dense,
            SpStrategy::Ring,
            true,
        );
        assert_expected_overlap(
            &format!("overlap ring dense n={n}"),
            &report,
            &shape,
            Strategy::Sequence { n },
            AttnPattern::Dense,
            true,
        );
    }
    for n in [1usize, 2, 4] {
        let (report, shape) = measure_overlap(
            NativeConfig { model: BERT_TINY_Z4, ring: n, ulysses: true, ..NativeConfig::tiny() },
            AttnPattern::Dense,
            SpStrategy::Ulysses,
            true,
        );
        assert_expected_overlap(
            &format!("overlap ulysses dense n={n}"),
            &report,
            &shape,
            Strategy::Ulysses { n },
            AttnPattern::Dense,
            true,
        );
    }
}

#[test]
fn ulysses_dense_peaks_match_closed_forms() {
    for n in [1usize, 2, 4] {
        let (report, shape) = measure(
            NativeConfig { model: BERT_TINY_Z4, ring: n, ulysses: true, ..NativeConfig::tiny() },
            AttnPattern::Dense,
            SpStrategy::Ulysses,
        );
        assert_expected(
            &format!("ulysses dense n={n}"),
            &report,
            &shape,
            Strategy::Ulysses { n },
            AttnPattern::Dense,
        );
    }
}
