//! Offline stub of the `xla` crate (the xla-rs PJRT bindings).
//!
//! The `backend-xla` feature of `seqpar` compiles against exactly this API
//! surface.  The stub keeps that feature *buildable* in environments with
//! no vendored xla-rs: every entry point returns a descriptive error at
//! runtime instead of executing HLO.  To run the real PJRT path, point the
//! `xla` dependency in `rust/Cargo.toml` at an xla-rs checkout — the
//! signatures below mirror the subset of its API that
//! `seqpar::backend::xla_pjrt` uses.

use std::borrow::Borrow;
use std::fmt;

/// Error type standing in for `xla::Error`; `Display` is all seqpar needs.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "xla stub: this build links the offline xla stub; point the `xla` \
         dependency in rust/Cargo.toml at a real xla-rs checkout to enable \
         the PJRT backend (or use the default native backend)"
            .to_string(),
    ))
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Marker for element types `Literal::to_vec` can extract.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

pub struct PjRtClient(());
pub struct PjRtLoadedExecutable(());
pub struct PjRtBuffer(());
pub struct HloModuleProto(());
pub struct XlaComputation(());
pub struct Literal(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable()
    }
}
