"""Pytest bootstrap: make `python/` importable so the prescribed
`pytest python/tests/` invocation works from the repository root
(the suite imports `compile.*`)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
