"""Shared helpers for the Pallas kernels (L1).

All kernels in this package are written against TPU-style constraints —
block shapes sized for a ~16 MiB VMEM scratchpad and MXU-aligned (multiples
of 8x128 for f32, with the contraction dimension a multiple of the head
size) — but are executed with ``interpret=True`` because the CPU PJRT
client cannot run Mosaic custom-calls.  The block-shape logic is therefore
*structural*: it determines the HBM<->VMEM schedule that would be used on a
real TPU, and `vmem_bytes` lets the AOT pipeline report the estimated VMEM
footprint per kernel (recorded in DESIGN.md / EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import dataclasses

# Target VMEM budget per core (bytes).  TPU v4 has 16 MiB per core; we keep
# a safety margin for the compiler's own scratch.
VMEM_BUDGET = 12 * 1024 * 1024

# MXU systolic array native tile (rows x cols for f32 inputs).
MXU_TILE = (8, 128)


def largest_divisor_at_most(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= ``cap``.

    Used to pick block sizes: Pallas grids require the block shape to divide
    the array shape, and we want blocks as close to the MXU-friendly cap as
    possible without padding.
    """
    if n <= 0:
        raise ValueError(f"size must be positive, got {n}")
    cap = max(1, min(cap, n))
    for d in range(cap, 0, -1):
        if n % d == 0:
            return d
    return 1


def pick_block(n: int, preferred: int = 128) -> int:
    """Pick a block size for dimension ``n`` close to ``preferred``."""
    return largest_divisor_at_most(n, preferred)


@dataclasses.dataclass(frozen=True)
class KernelFootprint:
    """Static VMEM/MXU estimate for one kernel configuration."""

    name: str
    block_shapes: tuple
    vmem_bytes: int
    mxu_flops_per_block: int
    bytes_per_block: int

    @property
    def arithmetic_intensity(self) -> float:
        """FLOPs per byte moved HBM<->VMEM — roofline x-coordinate."""
        if self.bytes_per_block == 0:
            return float("inf")
        return self.mxu_flops_per_block / self.bytes_per_block

    def summary(self) -> str:
        return (
            f"{self.name}: blocks={self.block_shapes} "
            f"vmem={self.vmem_bytes / 1024:.1f}KiB "
            f"AI={self.arithmetic_intensity:.1f} flop/B"
        )


def vmem_bytes(*block_shapes, dtype_bytes: int = 4) -> int:
    """Total VMEM held by a set of resident blocks."""
    total = 0
    for shape in block_shapes:
        n = dtype_bytes
        for d in shape:
            n *= d
        total += n
    return total


def assert_fits_vmem(name: str, *block_shapes, dtype_bytes: int = 4) -> int:
    used = vmem_bytes(*block_shapes, dtype_bytes=dtype_bytes)
    if used > VMEM_BUDGET:
        raise ValueError(
            f"kernel {name}: block working set {used} B exceeds VMEM budget "
            f"{VMEM_BUDGET} B; shrink block shapes {block_shapes}"
        )
    return used
