"""Fused MLP kernels (L1).

The Transformer MLP block (paper Eq. 2):  Y = GeLU(X A),  Z = Y B.

Under sequence parallelism the MLP weights are REPLICATED (no column/row
split — that is Megatron's trick) and each device runs the full block on
its own L/N-token slice, which is exactly why the block needs zero
communication (paper Table 1).  The kernels below therefore compute plain
dense layers; what makes them L1-worthy is the fusion: GeLU is applied in
the GEMM epilogue while the output tile is still in VMEM, saving one full
HBM round-trip of the (L/N, 4H) activation.

``gelu_linear``  : GeLU(x @ w + b)   — first MLP GEMM, fused activation
``linear``       : x @ w + b         — second MLP GEMM / any projection
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _gelu(x):
    # tanh-approximate GeLU, matching Megatron-LM's fused implementation.
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x * x * x)))


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str):
    x = x_ref[...]          # [bm, H]
    w = w_ref[...]          # [H, bn]
    b = b_ref[...]          # [bn]
    y = jax.lax.dot_general(
        x, w, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) + b[None, :]
    if activation == "gelu":
        y = _gelu(y)
    o_ref[...] = y.astype(o_ref.dtype)


def _call(x, w, b, activation, block_m, block_n):
    m, h = x.shape
    hw, n = w.shape
    if hw != h or b.shape != (n,):
        raise ValueError(f"shape mismatch: x{x.shape} w{w.shape} b{b.shape}")
    bm = common.pick_block(m, block_m)
    bn = common.pick_block(n, block_n)
    common.assert_fits_vmem("mlp", (bm, h), (h, bn), (bm, bn))
    return pl.pallas_call(
        functools.partial(_matmul_kernel, activation=activation),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, h), lambda i, j: (i, 0)),
            pl.BlockSpec((h, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(x, w, b)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def gelu_linear(x, w, b, *, block_m: int = 128, block_n: int = 128):
    """GeLU(x @ w + b) with the activation fused into the GEMM epilogue.

    x: [M, H] (M = B * L/N tokens), w: [H, N], b: [N].
    """
    return _call(x, w, b, "gelu", block_m, block_n)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def linear(x, w, b, *, block_m: int = 128, block_n: int = 128):
    """x @ w + b."""
    return _call(x, w, b, "none", block_m, block_n)


def footprint(m: int, h: int, n: int, block_m: int = 128, block_n: int = 128):
    bm = common.pick_block(m, block_m)
    bn = common.pick_block(n, block_n)
    blocks = ((bm, h), (h, bn), (bm, bn))
    return common.KernelFootprint(
        name="mlp_gemm",
        block_shapes=blocks,
        vmem_bytes=common.vmem_bytes(*blocks),
        mxu_flops_per_block=2 * bm * bn * h,
        bytes_per_block=common.vmem_bytes(*blocks),
    )
