"""L1 — Pallas kernels for the sequence-parallelism reproduction.

Every kernel is authored against TPU constraints (VMEM-sized blocks, MXU
tiles) but executed with ``interpret=True``; see DESIGN.md §4.
"""

from .ring_scores import ring_scores
from .ring_av import ring_av
from .softmax import softmax_rows
from .mlp import gelu_linear, linear
from .layernorm import layernorm
from .linformer import linformer_project

__all__ = [
    "ring_scores",
    "ring_av",
    "softmax_rows",
    "gelu_linear",
    "linear",
    "layernorm",
    "linformer_project",
]
