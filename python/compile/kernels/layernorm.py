"""LayerNorm kernel (L1).

Row-tiled layer normalization over the hidden dimension.  Under sequence
parallelism LayerNorm is purely local (statistics are per-token, and each
device owns whole tokens), so no communication is needed — contrast with
Megatron where the hidden dim is intact too, but the surrounding GEMMs
force all-reduces.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common

EPS = 1e-5


def _kernel(x_ref, g_ref, b_ref, o_ref):
    x = x_ref[...]                       # [bm, H]
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    norm = (x - mean) * jax.lax.rsqrt(var + EPS)
    o_ref[...] = (norm * g_ref[...][None, :] + b_ref[...][None, :]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m",))
def layernorm(x, gamma, beta, *, block_m: int = 128):
    """LayerNorm over the last axis.  x: [M, H]; gamma/beta: [H]."""
    m, h = x.shape
    if gamma.shape != (h,) or beta.shape != (h,):
        raise ValueError(f"param shape mismatch: {gamma.shape} {beta.shape} vs H={h}")
    bm = common.pick_block(m, block_m)
    common.assert_fits_vmem("layernorm", (bm, h), (bm, h))
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((m, h), jnp.float32),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, h), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, h), lambda i: (i, 0)),
        interpret=True,
    )(x, gamma, beta)
