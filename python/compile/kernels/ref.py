"""Pure-jnp oracles for every L1 kernel and for the ring decomposition.

These are the correctness ground truth: pytest sweeps shapes/dtypes with
hypothesis and asserts each Pallas kernel (interpret=True) matches its
oracle, and that the ring-decomposed attention equals monolithic attention.
Nothing here is ever lowered to artifacts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-5


def gelu(x):
    """tanh-approximate GeLU (matches Megatron's fused kernel)."""
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x ** 3)))


def scores(q, k):
    """[B,Z,Lq,A] x [B,Z,Lk,A] -> [B,Z,Lq,Lk], scaled."""
    a = q.shape[-1]
    return jnp.einsum("bzqa,bzka->bzqk", q, k) / jnp.sqrt(jnp.float32(a))


def softmax(x):
    return jax.nn.softmax(x, axis=-1)


def av(s, v):
    """[B,Z,Lq,Lk] x [B,Z,Lk,A] -> [B,Z,Lq,A]."""
    return jnp.einsum("bzqk,bzka->bzqa", s, v)


def attention(q, k, v):
    """Monolithic multi-head attention (the thing RSA must reproduce)."""
    return av(softmax(scores(q, k)), v)


def ring_attention(q_chunks, k_chunks, v_chunks):
    """RSA computed chunk-wise in pure jnp — the L2-level oracle.

    Args:
      q_chunks/k_chunks/v_chunks: lists of N arrays [B, Z, L/N, A].

    Returns:
      list of N arrays [B, Z, L/N, A]: attention output per device.

    Mirrors exactly what the rust coordinator does: stage 1 assembles the
    full score rows by rotating key chunks; softmax; stage 2 accumulates
    output by rotating value chunks (Eq. 4: O^n = sum_i S_i^n V_i).
    """
    n = len(q_chunks)
    outputs = []
    for dev in range(n):
        parts = [scores(q_chunks[dev], k_chunks[i]) for i in range(n)]
        s = softmax(jnp.concatenate(parts, axis=-1))
        lk = k_chunks[0].shape[2]
        acc = jnp.zeros_like(q_chunks[dev])
        for i in range(n):
            s_i = s[..., i * lk:(i + 1) * lk]
            acc = acc + av(s_i, v_chunks[i])
        outputs.append(acc)
    return outputs


def mlp(x, w1, b1, w2, b2):
    """Transformer MLP block: GeLU(x W1 + b1) W2 + b2."""
    return gelu(x @ w1 + b1) @ w2 + b2


def layernorm(x, gamma, beta):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + EPS) * gamma + beta


def linformer_project(e, x):
    """[K, Lc] x [B, Z, Lc, A] -> [B, Z, K, A]."""
    return jnp.einsum("kl,bzla->bzka", e, x)


def linformer_attention(q, k, v, e_k, e_v):
    """Full Linformer attention: project K/V to length K, then attend."""
    kp = linformer_project(e_k, k)
    vp = linformer_project(e_v, v)
    return av(softmax(scores(q, kp)), vp)
