"""Ring-QK^T step kernel (L1).

One step of Ring Self-Attention stage 1 (paper §3.1, Fig. 2a): the local
query chunk ``q`` scores against one circulating key chunk ``k``:

    s = q @ k^T / sqrt(A)

Shapes (per device, per ring step):
    q: [B, Z, Lq, A]   local query chunk (Lq = L/N)
    k: [B, Z, Lk, A]   key chunk currently held (own, then received N-1x)
    s: [B, Z, Lq, Lk]  partial attention scores for this step

The rust coordinator (L3) calls this executable N times per attention layer,
rotating ``k`` around the ring between calls, and concatenates the partial
scores along the last axis to assemble S^n in R^{Lq x L}.

TPU mapping: grid over (B*Z, Lq/bq, Lk/bk); each program holds a
(bq, A) query tile, a (bk, A) key tile and a (bq, bk) output tile in VMEM
and issues one MXU contraction over A.  ``interpret=True`` on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _kernel(q_ref, k_ref, o_ref, *, scale: float):
    q = q_ref[0]  # [bq, A]
    k = k_ref[0]  # [bk, A]
    s = jax.lax.dot_general(
        q,
        k,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[0] = (s * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def ring_scores(q, k, *, block_q: int = 128, block_k: int = 128):
    """Partial attention scores for one ring step.

    Args:
      q: [B, Z, Lq, A] local queries.
      k: [B, Z, Lk, A] circulating keys.
      block_q/block_k: preferred tile sizes along the two sequence dims.

    Returns:
      [B, Z, Lq, Lk] scaled scores (pre-softmax).
    """
    b, z, lq, a = q.shape
    bk_, zk_, lk, ak = k.shape
    if (b, z, a) != (bk_, zk_, ak):
        raise ValueError(f"q/k shape mismatch: {q.shape} vs {k.shape}")
    scale = 1.0 / (a ** 0.5)

    bq = common.pick_block(lq, block_q)
    bk = common.pick_block(lk, block_k)
    common.assert_fits_vmem("ring_scores", (bq, a), (bk, a), (bq, bk))

    qf = q.reshape(b * z, lq, a)
    kf = k.reshape(b * z, lk, a)
    grid = (b * z, lq // bq, lk // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        out_shape=jax.ShapeDtypeStruct((b * z, lq, lk), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, a), lambda n, i, j: (n, i, 0)),
            pl.BlockSpec((1, bk, a), lambda n, i, j: (n, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, bk), lambda n, i, j: (n, i, j)),
        interpret=True,
    )(qf, kf)
    return out.reshape(b, z, lq, lk)


def footprint(lq: int, lk: int, a: int, block_q: int = 128, block_k: int = 128):
    """Static VMEM/MXU estimate for DESIGN.md §Perf."""
    bq = common.pick_block(lq, block_q)
    bk = common.pick_block(lk, block_k)
    blocks = ((bq, a), (bk, a), (bq, bk))
    return common.KernelFootprint(
        name="ring_scores",
        block_shapes=blocks,
        vmem_bytes=common.vmem_bytes(*blocks),
        mxu_flops_per_block=2 * bq * bk * a,
        bytes_per_block=common.vmem_bytes(*blocks),
    )
