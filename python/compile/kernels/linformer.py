"""Linformer projection kernel (L1) — sparse-attention extension.

Paper §4.3 / Table 3: to push the sequence-length upper bound, keys and
values are projected from length L down to a fixed dimension K before
attention (Linformer).  Under sequence parallelism each device holds an
E-chunk  E^n in R^{K x L/N}  of the projection matrix and computes a
*partial* projection of its local chunk:

    P^n = E^n @ X^n      with  X^n in [B, Z, L/N, A]  ->  [B, Z, K, A]

The full projection  P = sum_n P^n  is assembled by one all-reduce in the
rust coordinator (L3).  Every L-carrying term is divided by N (Table 3),
which is what makes the length upper bound scale ~linearly with devices.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _kernel(e_ref, x_ref, o_ref):
    e = e_ref[...]      # [K, Lc]
    x = x_ref[0]        # [Lc, A]
    o_ref[0] = jax.lax.dot_general(
        e, x, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


@jax.jit
def linformer_project(e, x):
    """Partial Linformer projection of a local chunk.

    Args:
      e: [K, Lc] local slice of the projection matrix (Lc = L/N).
      x: [B, Z, Lc, A] local key or value chunk.

    Returns:
      [B, Z, K, A] partial projection (summed across devices by L3).
    """
    k, lc = e.shape
    b, z, lcx, a = x.shape
    if lcx != lc:
        raise ValueError(f"chunk length mismatch: E has {lc}, x has {lcx}")
    common.assert_fits_vmem("linformer_project", (k, lc), (lc, a), (k, a))
    xf = x.reshape(b * z, lc, a)
    out = pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((b * z, k, a), jnp.float32),
        grid=(b * z,),
        in_specs=[
            pl.BlockSpec((k, lc), lambda n: (0, 0)),
            pl.BlockSpec((1, lc, a), lambda n: (n, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, k, a), lambda n: (n, 0, 0)),
        interpret=True,
    )(e, xf)
    return out.reshape(b, z, k, a)
