"""Ring-AV step kernel (L1).

One step of Ring Self-Attention stage 2 (paper §3.1, Fig. 2b, Eq. 4):

    acc' = acc + s_i @ v_i

Shapes (per device, per ring step):
    s:   [B, Z, Lq, Lk]  the softmaxed score columns for the value chunk
                         currently held (S_i^n after column splitting)
    v:   [B, Z, Lk, A]   circulating value chunk
    acc: [B, Z, Lq, A]   running output accumulator O^n

The accumulator stays resident across ring steps.  On a real TPU the
(bq, A) accumulator tile would stay in VMEM for the whole inner loop — the
paper writes O^n back to HBM each step; fusing the accumulate into the
GEMM epilogue is our BlockSpec-level improvement (DESIGN.md §4).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _kernel(s_ref, v_ref, acc_ref, o_ref):
    s = s_ref[0]    # [bq, Lk]
    v = v_ref[0]    # [Lk, A]
    acc = acc_ref[0]  # [bq, A]
    o = jax.lax.dot_general(
        s,
        v,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[0] = (acc + o).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q",))
def ring_av(s, v, acc, *, block_q: int = 128):
    """One accumulating S_i @ V_i step of RSA stage 2.

    Args:
      s:   [B, Z, Lq, Lk] softmax probabilities for this value chunk.
      v:   [B, Z, Lk, A] circulating values.
      acc: [B, Z, Lq, A] accumulator (zeros on the first step).

    Returns:
      [B, Z, Lq, A] updated accumulator.
    """
    b, z, lq, lk = s.shape
    bv, zv, lkv, a = v.shape
    if (b, z, lk) != (bv, zv, lkv):
        raise ValueError(f"s/v shape mismatch: {s.shape} vs {v.shape}")
    if acc.shape != (b, z, lq, a):
        raise ValueError(f"acc shape {acc.shape} != {(b, z, lq, a)}")

    bq = common.pick_block(lq, block_q)
    common.assert_fits_vmem("ring_av", (bq, lk), (lk, a), (bq, a), (bq, a))

    sf = s.reshape(b * z, lq, lk)
    vf = v.reshape(b * z, lk, a)
    af = acc.reshape(b * z, lq, a)
    grid = (b * z, lq // bq)
    out = pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((b * z, lq, a), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, lk), lambda n, i: (n, i, 0)),
            pl.BlockSpec((1, lk, a), lambda n, i: (n, 0, 0)),
            pl.BlockSpec((1, bq, a), lambda n, i: (n, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, a), lambda n, i: (n, i, 0)),
        interpret=True,
    )(sf, vf, af)
    return out.reshape(b, z, lq, a)


def footprint(lq: int, lk: int, a: int, block_q: int = 128):
    bq = common.pick_block(lq, block_q)
    blocks = ((bq, lk), (lk, a), (bq, a), (bq, a))
    return common.KernelFootprint(
        name="ring_av",
        block_shapes=blocks,
        vmem_bytes=common.vmem_bytes(*blocks),
        mxu_flops_per_block=2 * bq * lk * a,
        bytes_per_block=common.vmem_bytes(*blocks),
    )
