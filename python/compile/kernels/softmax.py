"""Numerically-stable row softmax kernel (L1).

Applied to the assembled RSA score rows S^n in R^{Lq x L} after the
Ring-QK^T stage completes (the full row is needed for an exact softmax;
the streaming-max variant used by later ring-attention work is implemented
as an extension in ``model.py::rsa_online`` and validated against this).

Rows are tiled (``block_r`` rows per program) with the full row width
resident: even at the paper's 114K-token upper bound a f32 row is 456 KiB,
so a handful of rows fit VMEM comfortably.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _kernel(x_ref, o_ref):
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_r",))
def softmax_rows(x, *, block_r: int = 8):
    """Stable softmax over the last axis of ``x`` (any leading shape)."""
    *lead, width = x.shape
    rows = 1
    for d in lead:
        rows *= d
    xf = x.reshape(rows, width)
    br = common.pick_block(rows, block_r)
    common.assert_fits_vmem("softmax_rows", (br, width), (br, width))
    out = pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((rows, width), jnp.float32),
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, width), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, width), lambda i: (i, 0)),
        interpret=True,
    )(xf)
    return out.reshape(*lead, width)


def footprint(width: int, block_r: int = 8):
    blocks = ((block_r, width), (block_r, width))
    return common.KernelFootprint(
        name="softmax_rows",
        block_shapes=blocks,
        vmem_bytes=common.vmem_bytes(*blocks),
        mxu_flops_per_block=5 * block_r * width,  # max+sub+exp+sum+div (VPU)
        bytes_per_block=common.vmem_bytes(*blocks),
    )
