"""AOT pipeline: lower every step function to HLO text + write the manifest.

This is the ONLY place python touches the artifact directory.  After
``make artifacts`` the rust binary is self-contained: it loads
``artifacts/manifest.json``, compiles each ``*.hlo.txt`` on the PJRT CPU
client, and never imports python again.

Interchange is HLO **text** (not ``.serialize()``): jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Artifact naming: ``{step}__{sig}`` where sig joins each input's dims with
'x' and inputs with '_', prefixing i32 inputs with 'i'.  rust constructs
the same names (rust/src/runtime/registry.rs::art_name) — keep in sync.

Usage:
    python -m compile.aot --out ../artifacts --model bert-tiny \
        --batch 2 --seq-len 64 --ring 4 --tp 2 [--linformer 32] [--seed 0]
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import chain, configs, model, steps, tensorio

F32 = jnp.float32
I32 = jnp.int32


def spec(dims, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(dims), dtype)


def art_name(step: str, in_specs) -> str:
    parts = []
    for s in in_specs:
        pre = "i" if s.dtype == jnp.int32 else ""
        parts.append(pre + "x".join(str(d) for d in s.shape))
    return step + "__" + "_".join(parts)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# --------------------------------------------------------------------------
# Step enumeration — must mirror exactly what the rust engines request.
# --------------------------------------------------------------------------

def _tuplify(fn):
    """Wrap so every artifact returns a tuple (uniform unpacking in rust)."""
    @functools.wraps(fn)
    def wrapped(*args):
        out = fn(*args)
        return out if isinstance(out, tuple) else (out,)
    return wrapped


def attention_steps(b, z, lc, l_total, a):
    """Ring attention step set.  For tensor parallelism lc == l_total and
    z is the per-device head count — same artifacts, different shapes."""
    qs = [b, z, lc, a]
    ss = [b, z, lc, lc]
    fl = [b, z, lc, l_total]
    return [
        ("scores_step", steps.scores_step, [spec(qs), spec(qs)]),
        ("softmax_fwd", steps.softmax_fwd, [spec(fl)]),
        ("av_step", steps.av_step, [spec(ss), spec(qs), spec(qs)]),
        ("attn_dp_step", steps.attn_dp_step, [spec(qs), spec(qs)]),
        ("softmax_bwd", steps.softmax_bwd, [spec(fl), spec(fl)]),
        ("attn_dq_step", steps.attn_dq_step, [spec(ss), spec(qs), spec(qs)]),
        ("attn_dk_step", steps.attn_dk_step, [spec(ss), spec(qs), spec(qs)]),
        ("attn_dv_step", steps.attn_dv_step, [spec(ss), spec(qs), spec(qs)]),
    ]


def fused_steps(cfg, b, lc, z, a, fp):
    """§Perf iteration 2 artifacts: fused qkv / add+ln / mlp.

    ``z``/``a`` describe the (possibly head-split) layout; ``fp`` the
    (possibly column-split) FFN width — so the same set instantiates the
    sequence-parallel AND tensor-parallel engines.
    """
    h = cfg.hidden
    m = b * lc
    za = z * a
    qs = [b, z, lc, a]
    return [
        (f"qkv_proj_b{b}",
         functools.partial(steps.qkv_proj, b=b, z=z, a=a),
         [spec([m, h]), spec([h, za]), spec([za]), spec([h, za]), spec([za]),
          spec([h, za]), spec([za])]),
        ("qkv_proj_bwd", steps.qkv_proj_bwd,
         [spec([m, h]), spec([h, za]), spec([h, za]), spec([h, za]),
          spec(qs), spec(qs), spec(qs)]),
        ("add_ln_fwd", steps.add_ln_fwd,
         [spec([m, h]), spec([m, h]), spec([h]), spec([h])]),
        ("mlp_fwd", steps.mlp_fwd,
         [spec([m, h]), spec([h, fp]), spec([fp]), spec([fp, h]), spec([h])]),
        ("mlp_bwd", steps.mlp_bwd,
         [spec([m, h]), spec([h, fp]), spec([fp]), spec([fp, h]), spec([h]),
          spec([m, h])]),
    ]


def local_steps(cfg, b, lc, l_global, z, a):
    """Per-token-slice layers shared by all engines (shapes differ only in
    M = b * lc and the head split).  ``z``/``a`` describe the head layout
    produced by to_heads; the hidden width of qkv outputs is z * a."""
    h, f, v = cfg.hidden, cfg.ffn, cfg.vocab
    m = b * lc
    za = z * a
    norm_mlm = float(b * l_global)
    out = [
        ("embed_fwd", steps.embed_fwd, [spec([b, lc], I32), spec([v, h]), spec([lc, h])]),
        ("embed_bwd", steps.embed_bwd, [spec([b, lc], I32), spec([v, h]), spec([lc, h]), spec([m, h])]),
        ("ln_fwd", steps.ln_fwd, [spec([m, h]), spec([h]), spec([h])]),
        ("ln_bwd", steps.ln_bwd, [spec([m, h]), spec([h]), spec([h]), spec([m, h])]),
        ("linear_fwd", steps.linear_fwd, [spec([m, h]), spec([h, za]), spec([za])]),
        ("linear_bwd", steps.linear_bwd, [spec([m, h]), spec([h, za]), spec([za]), spec([m, za])]),
        # attention out-projection: [m, za] x [za, h]
        ("linear_fwd", steps.linear_fwd, [spec([m, za]), spec([za, h]), spec([h])]),
        ("linear_bwd", steps.linear_bwd, [spec([m, za]), spec([za, h]), spec([h]), spec([m, h])]),
        (f"to_heads_b{b}", functools.partial(steps.to_heads, b=b, z=z, a=a), [spec([m, za])]),
        ("from_heads", steps.from_heads, [spec([b, z, lc, a])]),
        ("add", steps.add, [spec([m, h]), spec([m, h])]),
        ("bias_add", steps.bias_add, [spec([m, h]), spec([h])]),
        ("mlm_loss", functools.partial(steps.mlm_loss, norm=norm_mlm),
         [spec([m, h]), spec([v, h]), spec([v]), spec([m], I32), spec([m])]),
        ("sop_loss", functools.partial(steps.sop_loss, batch=b, norm=float(b)),
         [spec([m, h]), spec([2, h]), spec([2]), spec([b], I32)]),
    ]
    return out


def mlp_steps(cfg, b, lc, fp):
    """MLP GEMMs; fp is the (possibly column-split) FFN width."""
    h = cfg.hidden
    m = b * lc
    return [
        ("gelu_linear_fwd", steps.gelu_linear_fwd, [spec([m, h]), spec([h, fp]), spec([fp])]),
        ("gelu_linear_bwd", steps.gelu_linear_bwd, [spec([m, h]), spec([h, fp]), spec([fp]), spec([m, fp])]),
        ("linear_fwd", steps.linear_fwd, [spec([m, fp]), spec([fp, h]), spec([h])]),
        ("linear_bwd", steps.linear_bwd, [spec([m, fp]), spec([fp, h]), spec([h]), spec([m, h])]),
    ]


def enumerate_seqpar(cfg, b, l, n):
    """Artifacts for the sequence-parallel engine at ring size n."""
    assert l % n == 0
    lc = l // n
    z, a = cfg.heads, cfg.head_dim
    arts = []
    arts += local_steps(cfg, b, lc, l, z, a)
    arts += mlp_steps(cfg, b, lc, cfg.ffn)
    arts += attention_steps(b, z, lc, l, a)
    arts += fused_steps(cfg, b, lc, z, a, cfg.ffn)
    return arts


def enumerate_tensorpar(cfg, b, l, t):
    """Artifacts for the Megatron baseline at TP size t (t=1 == serial)."""
    assert cfg.heads % t == 0 and cfg.ffn % t == 0
    zp = cfg.heads // t
    fp = cfg.ffn // t
    a = cfg.head_dim
    arts = []
    arts += local_steps(cfg, b, l, l, zp, a)
    arts += mlp_steps(cfg, b, l, fp)
    arts += attention_steps(b, zp, l, l, a)
    arts += fused_steps(cfg, b, l, zp, a, fp)
    return arts


def enumerate_linformer(cfg, b, l, n, kproj):
    """Forward-only Linformer + sequence parallelism (paper §4.3)."""
    assert l % n == 0
    lc = l // n
    z, a = cfg.heads, cfg.head_dim
    qs = [b, z, lc, a]
    ks = [b, z, kproj, a]
    sk = [b, z, lc, kproj]
    return [
        ("linformer_proj", steps.linformer_proj_step, [spec([kproj, lc]), spec(qs)]),
        ("scores_step", steps.scores_step, [spec(qs), spec(ks)]),
        ("softmax_fwd", steps.softmax_fwd, [spec(sk)]),
        ("av_step", steps.av_step, [spec(sk), spec(ks), spec(qs)]),
    ]


# --------------------------------------------------------------------------
# Lowering driver
# --------------------------------------------------------------------------

def lower_all(art_list, out_dir, manifest):
    os.makedirs(out_dir, exist_ok=True)
    for step_name, fn, in_specs in art_list:
        name = art_name(step_name, in_specs)
        if name in manifest["artifacts"]:
            continue
        wrapped = _tuplify(fn)
        # keep_unused: several bwd steps take inputs whose VALUE the
        # gradient doesn't need (e.g. ln_bwd's beta) — without this flag
        # jax drops them from the HLO signature and the rust call site
        # (which always passes the full manifest signature) would mismatch.
        lowered = jax.jit(wrapped, keep_unused=True).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = name + ".hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(wrapped, *in_specs)
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [
                {"dims": list(s.shape), "dtype": "i32" if s.dtype == jnp.int32 else "f32"}
                for s in in_specs
            ],
            "outputs": [
                {"dims": list(s.shape), "dtype": "i32" if s.dtype == jnp.int32 else "f32"}
                for s in out_shapes
            ],
        }
        print(f"  lowered {name} ({len(text)} chars)")


def export_params(cfg, seq_len, seed, out_dir, manifest):
    pdir = os.path.join(out_dir, "params")
    os.makedirs(pdir, exist_ok=True)
    params = model.init_params(cfg, seq_len, seed)
    for name, _shape in model.param_spec(cfg, seq_len):
        safe = name.replace(".", "_")
        tensorio.save(os.path.join(pdir, safe + ".tensor"), np.asarray(params[name]))
        manifest["params"].append({
            "name": name,
            "dims": list(params[name].shape),
            "file": f"params/{safe}.tensor",
        })
    return params


def export_goldens(cfg, params, b, l, ring, out_dir, manifest, seed):
    """Golden inputs + expected outputs from the validated python chain."""
    gdir = os.path.join(out_dir, "goldens")
    os.makedirs(gdir, exist_ok=True)
    key = jax.random.PRNGKey(seed + 1000)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    ids = jax.random.randint(k1, (b, l), 4, cfg.vocab)
    labels = jax.random.randint(k2, (b, l), 4, cfg.vocab)
    mask = (jax.random.uniform(k3, (b, l)) < 0.15).astype(F32)
    sop = jax.random.randint(k4, (b,), 0, 2)

    res = chain.seqpar_forward_backward(params, ids, labels, mask, sop, cfg, ring)

    def g(name, arr):
        tensorio.save(os.path.join(gdir, name + ".tensor"), np.asarray(arr))
        manifest["goldens"][name] = f"goldens/{name}.tensor"

    g("ids", ids)
    g("labels", labels)
    g("mask", mask)
    g("sop_labels", sop)
    g("loss", np.array([res.loss, res.mlm, res.sop], np.float32))
    for d, h in enumerate(res.hidden_chunks):
        g(f"hidden_dev{d}", h)
    for pname in ("layer0.wq", "mlm_b", "tok_emb"):
        g("grad_" + pname.replace(".", "_"), res.grads[pname])

    # quickstart goldens: one RSA attention call, chunked q/k/v + outputs
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed + 2000), 3)
    z, a = cfg.heads, cfg.head_dim
    lc = l // ring
    from .kernels import ref
    q = jax.random.normal(kq, (b, z, l, a), F32)
    kk_ = jax.random.normal(kk, (b, z, l, a), F32)
    vv = jax.random.normal(kv, (b, z, l, a), F32)
    qc = [q[:, :, i * lc:(i + 1) * lc] for i in range(ring)]
    kc = [kk_[:, :, i * lc:(i + 1) * lc] for i in range(ring)]
    vc = [vv[:, :, i * lc:(i + 1) * lc] for i in range(ring)]
    outs = ref.ring_attention(qc, kc, vc)
    for i in range(ring):
        g(f"qs_dev{i}", qc[i])
        g(f"ks_dev{i}", kc[i])
        g(f"vs_dev{i}", vc[i])
        g(f"attn_out_dev{i}", outs[i])


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--model", default="bert-tiny")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ring", type=int, default=4)
    ap.add_argument("--tp", type=int, default=2)
    ap.add_argument("--linformer", type=int, default=0,
                    help="Linformer projection dim K (0 = skip)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--skip-goldens", action="store_true")
    args = ap.parse_args()

    cfg = configs.get(args.model)
    manifest = {
        "model": args.model,
        "batch": args.batch,
        "seq_len": args.seq_len,
        "ring": args.ring,
        "tp": args.tp,
        "linformer_k": args.linformer,
        "hidden": cfg.hidden,
        "heads": cfg.heads,
        "head_dim": cfg.head_dim,
        "ffn": cfg.ffn,
        "layers": cfg.layers,
        "vocab": cfg.vocab,
        "seed": args.seed,
        "artifacts": {},
        "params": [],
        "goldens": {},
    }

    arts = []
    arts += enumerate_seqpar(cfg, args.batch, args.seq_len, args.ring)
    arts += enumerate_tensorpar(cfg, args.batch, args.seq_len, args.tp)
    arts += enumerate_tensorpar(cfg, args.batch, args.seq_len, 1)  # serial
    if args.linformer:
        arts += enumerate_linformer(cfg, args.batch, args.seq_len, args.ring,
                                    args.linformer)

    print(f"lowering {args.model} B={args.batch} L={args.seq_len} "
          f"ring={args.ring} tp={args.tp} ...")
    lower_all(arts, args.out, manifest)
    params = export_params(cfg, args.seq_len, args.seed, args.out, manifest)
    if not args.skip_goldens:
        export_goldens(cfg, params, args.batch, args.seq_len, args.ring,
                       args.out, manifest, args.seed)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['artifacts'])} artifacts, "
          f"{len(manifest['params'])} params -> {args.out}/manifest.json")


if __name__ == "__main__":
    main()
