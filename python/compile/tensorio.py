"""Binary tensor interchange between the python compile path and rust.

Format (little-endian), implemented identically in rust/src/tensor/io.rs:

    magic   b"SPT1"
    dtype   u8      0 = f32, 1 = i32
    ndim    u8
    dims    u64 * ndim
    data    dtype * prod(dims), C-order

Used for initial parameters, golden inputs/outputs, and example data.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"SPT1"
_DTYPES = {0: np.float32, 1: np.int32}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}


def save(path, arr) -> None:
    arr = np.asarray(arr)
    if arr.dtype not in _CODES:
        if np.issubdtype(arr.dtype, np.floating):
            arr = arr.astype(np.float32)
        elif np.issubdtype(arr.dtype, np.integer):
            arr = arr.astype(np.int32)
        else:
            raise TypeError(f"unsupported dtype {arr.dtype}")
    code = _CODES[arr.dtype]
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<BB", code, arr.ndim))
        f.write(struct.pack(f"<{arr.ndim}Q", *arr.shape))
        f.write(np.ascontiguousarray(arr).tobytes())


def load(path) -> np.ndarray:
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        code, ndim = struct.unpack("<BB", f.read(2))
        dims = struct.unpack(f"<{ndim}Q", f.read(8 * ndim))
        dtype = _DTYPES[code]
        data = np.frombuffer(f.read(), dtype=dtype)
    return data.reshape(dims)
