"""Pure-python simulation of the rust coordinator's step/comm schedules.

This module executes EXACTLY the chains the rust engines run — same step
functions (``steps.py``), same ring rotations, same all-reduce points —
with devices simulated sequentially.  It serves three purposes:

1. Schedule validation: ``pytest`` compares these chains against
   ``jax.grad`` of the monolithic model, so any schedule bug is caught
   before it is re-implemented in rust.
2. Golden export: ``aot.py`` runs the chain to produce the reference
   outputs that ``examples/quickstart.rs`` and the rust integration tests
   assert against.
3. Living documentation of the wire protocol (what moves, when).

Ring convention (matches rust/src/parallel/sequence):  at ring step ``t``
(t = 0..N-1), device ``d`` holds the chunk ORIGINALLY OWNED by device
``(d - t) mod N`` — chunks flow to the next-higher rank each step.
Accumulators that "ride the ring" use the same rotation, so after N steps
(N-1 sends) chunk i's accumulator is back home.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from . import steps
from .configs import ModelConfig


def chunk_owner(device: int, t: int, n: int) -> int:
    """Who originally owns the chunk that device ``device`` holds at step t."""
    return (device - t) % n


# --------------------------------------------------------------------------
# Sequence-parallel engine (the paper's contribution)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SeqParResult:
    loss: float
    mlm: float
    sop: float
    hidden_chunks: list            # final hidden per device [M, H]
    grads: dict                    # name -> global grad (pos_emb assembled)


def _rsa_forward(q, k_own, v_own, n_dev, dev, all_k, all_v):
    """RSA stages 1+2 for one device; all_k/all_v give the ring's contents.

    Returns (ctx, p) where p is stashed for backward.
    """
    lc = k_own.shape[2]
    l = lc * n_dev
    # stage 1: Ring-QK^T.  At step t we hold chunk (dev - t) % n.
    parts = [None] * n_dev
    for t in range(n_dev):
        src = chunk_owner(dev, t, n_dev)
        parts[src] = steps.scores_step(q, all_k[src])
    s = jnp.concatenate(parts, axis=-1)      # [B, Z, Lc, L] in global order
    p = steps.softmax_fwd(s)
    # stage 2: Ring-AV, Eq. 4.
    acc = jnp.zeros_like(q)
    for t in range(n_dev):
        src = chunk_owner(dev, t, n_dev)
        p_i = p[..., src * lc:(src + 1) * lc]
        acc = steps.av_step(p_i, all_v[src], acc)
    return acc, p


def _rsa_backward(d_ctx, q, p, all_k, all_v, n_dev, dev):
    """Hand-scheduled RSA backward for one device.

    Returns (dq, dk_contrib, dv_contrib) where dk_contrib[i] / dv_contrib[i]
    are THIS device's additive contributions to chunk i's gradients (in rust
    these ride the ring as accumulators; summing across devices here is the
    same reduction).
    """
    lc = all_k[0].shape[2]
    # ring pass of V: dP_i = dO V_i^T, and dV_i += P_i^T dO
    dp_parts = [None] * n_dev
    dv_contrib = [None] * n_dev
    for t in range(n_dev):
        src = chunk_owner(dev, t, n_dev)
        dp_parts[src] = steps.attn_dp_step(d_ctx, all_v[src])
        p_i = p[..., src * lc:(src + 1) * lc]
        dv_contrib[src] = steps.attn_dv_step(p_i, d_ctx, jnp.zeros_like(all_v[src]))
    dp = jnp.concatenate(dp_parts, axis=-1)
    ds = steps.softmax_bwd(p, dp)
    # ring pass of K: dQ += scale dS_i K_i, and dK_i += scale dS_i^T Q
    dq = jnp.zeros_like(q)
    dk_contrib = [None] * n_dev
    for t in range(n_dev):
        src = chunk_owner(dev, t, n_dev)
        ds_i = ds[..., src * lc:(src + 1) * lc]
        dq = steps.attn_dq_step(ds_i, all_k[src], dq)
        dk_contrib[src] = steps.attn_dk_step(ds_i, q, jnp.zeros_like(all_k[src]))
    return dq, dk_contrib, dv_contrib


def seqpar_forward_backward(params, ids, labels, mask, sop_labels,
                            cfg: ModelConfig, n_dev: int) -> SeqParResult:
    """Run the full sequence-parallel schedule on n_dev simulated devices."""
    b, l = ids.shape
    assert l % n_dev == 0, "sequence length must divide the ring size"
    lc = l // n_dev
    z, a = cfg.heads, cfg.head_dim
    norm_mlm = float(b * l)

    ids_c = [ids[:, d * lc:(d + 1) * lc] for d in range(n_dev)]
    lab_c = [labels[:, d * lc:(d + 1) * lc].reshape(-1) for d in range(n_dev)]
    mask_c = [mask[:, d * lc:(d + 1) * lc].reshape(-1) for d in range(n_dev)]
    pos_c = [params["pos_emb"][d * lc:(d + 1) * lc] for d in range(n_dev)]

    # ---- forward ----------------------------------------------------------
    x = [steps.embed_fwd(ids_c[d], params["tok_emb"], pos_c[d]) for d in range(n_dev)]
    stash = []  # per layer: dict of per-device activation lists
    for i in range(cfg.layers):
        pfx = f"layer{i}."
        st = {"x_in": x}
        q, k, v = [], [], []
        for d in range(n_dev):
            q.append(steps.to_heads(steps.linear_fwd(x[d], params[pfx + "wq"], params[pfx + "bq"]), b, z, a))
            k.append(steps.to_heads(steps.linear_fwd(x[d], params[pfx + "wk"], params[pfx + "bk"]), b, z, a))
            v.append(steps.to_heads(steps.linear_fwd(x[d], params[pfx + "wv"], params[pfx + "bv"]), b, z, a))
        st.update(q=q, k=k, v=v)
        ctx, p = [], []
        for d in range(n_dev):
            c, pp = _rsa_forward(q[d], k[d], v[d], n_dev, d, k, v)
            ctx.append(c)
            p.append(pp)
        st.update(ctx=ctx, p=p)
        attn = [steps.linear_fwd(steps.from_heads(ctx[d]), params[pfx + "wo"], params[pfx + "bo"]) for d in range(n_dev)]
        pre1 = [steps.add(x[d], attn[d]) for d in range(n_dev)]
        xm = [steps.ln_fwd(pre1[d], params[pfx + "ln1_g"], params[pfx + "ln1_b"]) for d in range(n_dev)]
        h = [steps.gelu_linear_fwd(xm[d], params[pfx + "w1"], params[pfx + "b1"]) for d in range(n_dev)]
        m2 = [steps.linear_fwd(h[d], params[pfx + "w2"], params[pfx + "b2"]) for d in range(n_dev)]
        pre2 = [steps.add(xm[d], m2[d]) for d in range(n_dev)]
        x = [steps.ln_fwd(pre2[d], params[pfx + "ln2_g"], params[pfx + "ln2_b"]) for d in range(n_dev)]
        st.update(pre1=pre1, xm=xm, h=h, pre2=pre2)
        stash.append(st)

    # ---- losses ------------------------------------------------------------
    g = {name: jnp.zeros_like(p) for name, p in params.items()}
    mlm_total = 0.0
    dx = [None] * n_dev
    for d in range(n_dev):
        lo, dxd, dw, db = steps.mlm_loss(x[d], params["mlm_w"], params["mlm_b"],
                                         lab_c[d], mask_c[d], norm_mlm)
        mlm_total += float(lo)
        dx[d] = dxd
        g["mlm_w"] = g["mlm_w"] + dw
        g["mlm_b"] = g["mlm_b"] + db
    sop, dx0, dsw, dsb = steps.sop_loss(x[0], params["sop_w"], params["sop_b"],
                                        sop_labels, b, float(b))
    dx[0] = dx[0] + dx0
    g["sop_w"] = g["sop_w"] + dsw
    g["sop_b"] = g["sop_b"] + dsb

    hidden = list(x)

    # ---- backward ----------------------------------------------------------
    for i in reversed(range(cfg.layers)):
        pfx = f"layer{i}."
        st = stash[i]
        new_dx = [None] * n_dev
        dq_flat, dk_all, dv_all = [None] * n_dev, [], []
        # ln2 -> mlp -> ln1 local chains per device
        d_pre2 = [None] * n_dev
        for d in range(n_dev):
            dpre, dg2, db2 = steps.ln_bwd(st["pre2"][d], params[pfx + "ln2_g"], params[pfx + "ln2_b"], dx[d])
            g[pfx + "ln2_g"] = g[pfx + "ln2_g"] + dg2
            g[pfx + "ln2_b"] = g[pfx + "ln2_b"] + db2
            d_pre2[d] = dpre
        dxm = [None] * n_dev
        for d in range(n_dev):
            dh, dw2, db2m = steps.linear_bwd(st["h"][d], params[pfx + "w2"], params[pfx + "b2"], d_pre2[d])
            g[pfx + "w2"] = g[pfx + "w2"] + dw2
            g[pfx + "b2"] = g[pfx + "b2"] + db2m
            dxmlp, dw1, db1m = steps.gelu_linear_bwd(st["xm"][d], params[pfx + "w1"], params[pfx + "b1"], dh)
            g[pfx + "w1"] = g[pfx + "w1"] + dw1
            g[pfx + "b1"] = g[pfx + "b1"] + db1m
            dxm[d] = steps.add(d_pre2[d], dxmlp)   # residual join
        d_pre1 = [None] * n_dev
        for d in range(n_dev):
            dpre, dg1, db1 = steps.ln_bwd(st["pre1"][d], params[pfx + "ln1_g"], params[pfx + "ln1_b"], dxm[d])
            g[pfx + "ln1_g"] = g[pfx + "ln1_g"] + dg1
            g[pfx + "ln1_b"] = g[pfx + "ln1_b"] + db1
            d_pre1[d] = dpre
        # attention out-proj backward
        d_ctx = [None] * n_dev
        for d in range(n_dev):
            dflat, dwo, dbo = steps.linear_bwd(steps.from_heads(st["ctx"][d]), params[pfx + "wo"], params[pfx + "bo"], d_pre1[d])
            g[pfx + "wo"] = g[pfx + "wo"] + dwo
            g[pfx + "bo"] = g[pfx + "bo"] + dbo
            d_ctx[d] = steps.to_heads(dflat, b, z, a)
        # RSA backward (ring)
        dk_sum = [jnp.zeros_like(st["k"][d]) for d in range(n_dev)]
        dv_sum = [jnp.zeros_like(st["v"][d]) for d in range(n_dev)]
        dq = [None] * n_dev
        for d in range(n_dev):
            dqd, dkc, dvc = _rsa_backward(d_ctx[d], st["q"][d], st["p"][d], st["k"], st["v"], n_dev, d)
            dq[d] = dqd
            for i2 in range(n_dev):
                dk_sum[i2] = dk_sum[i2] + dkc[i2]
                dv_sum[i2] = dv_sum[i2] + dvc[i2]
        # qkv projection backward + residual join
        for d in range(n_dev):
            xin = st["x_in"][d]
            dxq, dwq, dbq = steps.linear_bwd(xin, params[pfx + "wq"], params[pfx + "bq"], steps.from_heads(dq[d]))
            dxk, dwk, dbk = steps.linear_bwd(xin, params[pfx + "wk"], params[pfx + "bk"], steps.from_heads(dk_sum[d]))
            dxv, dwv, dbv = steps.linear_bwd(xin, params[pfx + "wv"], params[pfx + "bv"], steps.from_heads(dv_sum[d]))
            g[pfx + "wq"] = g[pfx + "wq"] + dwq
            g[pfx + "bq"] = g[pfx + "bq"] + dbq
            g[pfx + "wk"] = g[pfx + "wk"] + dwk
            g[pfx + "bk"] = g[pfx + "bk"] + dbk
            g[pfx + "wv"] = g[pfx + "wv"] + dwv
            g[pfx + "bv"] = g[pfx + "bv"] + dbv
            new_dx[d] = d_pre1[d] + dxq + dxk + dxv
        dx = new_dx

    # embeddings
    pos_grads = []
    for d in range(n_dev):
        dtok, dpos = steps.embed_bwd(ids_c[d], params["tok_emb"], pos_c[d], dx[d])
        g["tok_emb"] = g["tok_emb"] + dtok
        pos_grads.append(dpos)
    g["pos_emb"] = jnp.concatenate(pos_grads, axis=0)

    total = mlm_total + float(sop)
    return SeqParResult(total, mlm_total, float(sop), hidden, g)


# --------------------------------------------------------------------------
# Tensor-parallel baseline (Megatron-LM schedule)
# --------------------------------------------------------------------------

def tensorpar_forward_backward(params, ids, labels, mask, sop_labels,
                               cfg: ModelConfig, n_dev: int):
    """Megatron tensor-parallel schedule: attention heads and MLP columns
    split over n_dev devices; all-reduce after each block's second GEMM
    (forward) and at each block's input (backward).

    Weight slices per device d:
        wq/wk/wv columns  [H, Zp*A],  wo rows [Zp*A, H]
        w1 columns [H, F/N],          w2 rows [F/N, H]
    Replicated: embeddings, layernorms, biases of second GEMMs, heads.

    Returns (loss, mlm, sop, hidden [B*L,H], grads dict in GLOBAL layout).
    """
    b, l = ids.shape
    z, a, f = cfg.heads, cfg.head_dim, cfg.ffn
    assert z % n_dev == 0, "heads must divide TP size (Megatron's cap)"
    zp = z // n_dev
    fp = f // n_dev
    norm_mlm = float(b * l)

    g = {name: jnp.zeros_like(p) for name, p in params.items()}

    x = steps.embed_fwd(ids, params["tok_emb"], params["pos_emb"][:l])
    stash = []
    for i in range(cfg.layers):
        pfx = f"layer{i}."
        st = {"x_in": x}
        q, k, v, ctx, p = [], [], [], [], []
        for d in range(n_dev):
            cols = slice(d * zp * a, (d + 1) * zp * a)
            qd = steps.to_heads(steps.linear_fwd(x, params[pfx + "wq"][:, cols], params[pfx + "bq"][cols]), b, zp, a)
            kd = steps.to_heads(steps.linear_fwd(x, params[pfx + "wk"][:, cols], params[pfx + "bk"][cols]), b, zp, a)
            vd = steps.to_heads(steps.linear_fwd(x, params[pfx + "wv"][:, cols], params[pfx + "bv"][cols]), b, zp, a)
            s = steps.scores_step(qd, kd)
            pd = steps.softmax_fwd(s)
            cd = steps.av_step(pd, vd, jnp.zeros_like(qd))
            q.append(qd); k.append(kd); v.append(vd); p.append(pd); ctx.append(cd)
        # row-split out proj: partial sums all-reduced, bias added once
        partial = [
            steps.linear_fwd(steps.from_heads(ctx[d]),
                             params[pfx + "wo"][d * zp * a:(d + 1) * zp * a, :],
                             jnp.zeros((cfg.hidden,), jnp.float32))
            for d in range(n_dev)
        ]
        attn = steps.bias_add(sum(partial), params[pfx + "bo"])   # all-reduce
        pre1 = steps.add(x, attn)
        xm = steps.ln_fwd(pre1, params[pfx + "ln1_g"], params[pfx + "ln1_b"])
        h = []
        for d in range(n_dev):
            cols = slice(d * fp, (d + 1) * fp)
            h.append(steps.gelu_linear_fwd(xm, params[pfx + "w1"][:, cols], params[pfx + "b1"][cols]))
        partial2 = [
            steps.linear_fwd(h[d], params[pfx + "w2"][d * fp:(d + 1) * fp, :],
                             jnp.zeros((cfg.hidden,), jnp.float32))
            for d in range(n_dev)
        ]
        m2 = steps.bias_add(sum(partial2), params[pfx + "b2"])    # all-reduce
        pre2 = steps.add(xm, m2)
        x = steps.ln_fwd(pre2, params[pfx + "ln2_g"], params[pfx + "ln2_b"])
        st.update(q=q, k=k, v=v, p=p, ctx=ctx, pre1=pre1, xm=xm, h=h, pre2=pre2)
        stash.append(st)

    # heads are replicated: compute once (device-identical).
    lo, dx, dw, db = steps.mlm_loss(x, params["mlm_w"], params["mlm_b"],
                                    labels.reshape(-1), mask.reshape(-1), norm_mlm)
    g["mlm_w"] = dw
    g["mlm_b"] = db
    sop, dx0, dsw, dsb = steps.sop_loss(x, params["sop_w"], params["sop_b"],
                                        sop_labels, b, float(b))
    dx = dx + dx0
    g["sop_w"] = dsw
    g["sop_b"] = dsb

    hidden = x

    for i in reversed(range(cfg.layers)):
        pfx = f"layer{i}."
        st = stash[i]
        dpre2, dg2, db2 = steps.ln_bwd(st["pre2"], params[pfx + "ln2_g"], params[pfx + "ln2_b"], dx)
        g[pfx + "ln2_g"] = g[pfx + "ln2_g"] + dg2
        g[pfx + "ln2_b"] = g[pfx + "ln2_b"] + db2
        g[pfx + "b2"] = g[pfx + "b2"] + jnp.sum(dpre2, axis=0)
        dxm_partial = []
        for d in range(n_dev):
            rows = slice(d * fp, (d + 1) * fp)
            cols = slice(d * fp, (d + 1) * fp)
            dh, dw2, _ = steps.linear_bwd(st["h"][d], params[pfx + "w2"][rows, :],
                                          jnp.zeros((cfg.hidden,), jnp.float32), dpre2)
            g[pfx + "w2"] = g[pfx + "w2"].at[rows, :].add(dw2)
            dxd, dw1, db1m = steps.gelu_linear_bwd(st["xm"], params[pfx + "w1"][:, cols],
                                                   params[pfx + "b1"][cols], dh)
            g[pfx + "w1"] = g[pfx + "w1"].at[:, cols].add(dw1)
            g[pfx + "b1"] = g[pfx + "b1"].at[cols].add(db1m)
            dxm_partial.append(dxd)
        dxm = sum(dxm_partial) + dpre2          # all-reduce + residual
        dpre1, dg1, db1 = steps.ln_bwd(st["pre1"], params[pfx + "ln1_g"], params[pfx + "ln1_b"], dxm)
        g[pfx + "ln1_g"] = g[pfx + "ln1_g"] + dg1
        g[pfx + "ln1_b"] = g[pfx + "ln1_b"] + db1
        g[pfx + "bo"] = g[pfx + "bo"] + jnp.sum(dpre1, axis=0)
        dx_partial = []
        for d in range(n_dev):
            cols = slice(d * zp * a, (d + 1) * zp * a)
            rows = cols
            dflat, dwo, _ = steps.linear_bwd(steps.from_heads(st["ctx"][d]),
                                             params[pfx + "wo"][rows, :],
                                             jnp.zeros((cfg.hidden,), jnp.float32), dpre1)
            g[pfx + "wo"] = g[pfx + "wo"].at[rows, :].add(dwo)
            d_ctx = steps.to_heads(dflat, b, zp, a)
            dp = steps.attn_dp_step(d_ctx, st["v"][d])
            ds = steps.softmax_bwd(st["p"][d], dp)
            dq = steps.attn_dq_step(ds, st["k"][d], jnp.zeros_like(st["q"][d]))
            dk = steps.attn_dk_step(ds, st["q"][d], jnp.zeros_like(st["k"][d]))
            dv = steps.attn_dv_step(st["p"][d], d_ctx, jnp.zeros_like(st["v"][d]))
            dxq, dwq, dbq = steps.linear_bwd(st["x_in"], params[pfx + "wq"][:, cols], params[pfx + "bq"][cols], steps.from_heads(dq))
            dxk, dwk, dbk = steps.linear_bwd(st["x_in"], params[pfx + "wk"][:, cols], params[pfx + "bk"][cols], steps.from_heads(dk))
            dxv, dwv, dbv = steps.linear_bwd(st["x_in"], params[pfx + "wv"][:, cols], params[pfx + "bv"][cols], steps.from_heads(dv))
            g[pfx + "wq"] = g[pfx + "wq"].at[:, cols].add(dwq)
            g[pfx + "bq"] = g[pfx + "bq"].at[cols].add(dbq)
            g[pfx + "wk"] = g[pfx + "wk"].at[:, cols].add(dwk)
            g[pfx + "bk"] = g[pfx + "bk"].at[cols].add(dbk)
            g[pfx + "wv"] = g[pfx + "wv"].at[:, cols].add(dwv)
            g[pfx + "bv"] = g[pfx + "bv"].at[cols].add(dbv)
            dx_partial.append(dxq + dxk + dxv)
        dx = sum(dx_partial) + dpre1            # all-reduce + residual

    dtok, dpos = steps.embed_bwd(ids, params["tok_emb"], params["pos_emb"][:l], dx)
    g["tok_emb"] = g["tok_emb"] + dtok
    g["pos_emb"] = dpos

    return float(lo) + float(sop), float(lo), float(sop), hidden, g
