"""L2 — the step functions that become AOT artifacts.

Each function here is one *step* of the per-device computation.  The rust
coordinator (L3) chains these executables, inserting ring P2P / all-reduce
exactly where the paper's schedule requires (DESIGN.md §3).  Granularity is
chosen so that (a) all communication happens BETWEEN steps, in rust, and
(b) the same step instantiates the sequence-parallel engine, the Megatron
tensor-parallel baseline, and the serial engine — only the shapes differ.

Backward steps:  for the local layers (layernorm, linears, embeddings,
losses) we lower ``jax.vjp`` of the forward — the recompute-inside-vjp
(rematerialization) keeps the artifact self-contained.  For the ring
attention the backward is hand-scheduled (the whole point of the paper:
gradients of K/V chunks must ride the ring back to their home device), so
the bwd steps are explicit GEMMs:

    forward:  S = scale * Q K^T (assembled over ring),  P = softmax(S),
              O = sum_i P_i V_i                       (ring-accumulated)
    backward: dP_i = dO V_i^T                         (ring pass of V)
              dS   = P * (dP - rowsum(dP * P))        (local)
              dQ  += scale * dS_i K_i                 (ring pass of K)
              dK_i += scale * dS_i^T Q                (accumulator rides ring)
              dV_i += P_i^T dO                        (accumulator rides ring)

The pytest suite verifies that this chain, composed exactly as rust
composes it, equals ``jax.grad`` of monolithic attention.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import (
    gelu_linear,
    layernorm,
    linear,
    linformer_project,
    ring_av,
    ring_scores,
    softmax_rows,
)
from .kernels import ref

# NOTE on backward authoring: ``pallas_call`` has no autodiff rule, so the
# ``jax.vjp``-lowered backward steps differentiate the pure-jnp reference
# implementations from ``kernels/ref.py`` — numerically identical to the
# Pallas forwards (pytest asserts so) and the standard custom-VJP pairing.

# --------------------------------------------------------------------------
# Embeddings
# --------------------------------------------------------------------------

def embed_fwd(ids, tok_emb, pos_emb):
    """Token + position embeddings for a local chunk.

    ids: [B, Lc] int32; tok_emb: [V, H]; pos_emb: [Lc, H] (the device's
    slice of the position table).  Returns x: [B*Lc, H].
    """
    b, lc = ids.shape
    x = tok_emb[ids] + pos_emb[None, :, :]
    return x.reshape(b * lc, -1)


def embed_bwd(ids, tok_emb, pos_emb, dx):
    """VJP of embed_fwd w.r.t. (tok_emb, pos_emb)."""
    _, vjp = jax.vjp(lambda t, p: embed_fwd(ids, t, p), tok_emb, pos_emb)
    return vjp(dx)


# --------------------------------------------------------------------------
# LayerNorm
# --------------------------------------------------------------------------

def ln_fwd(x, gamma, beta):
    return layernorm(x, gamma, beta)


def ln_bwd(x, gamma, beta, dy):
    _, vjp = jax.vjp(ref.layernorm, x, gamma, beta)
    return vjp(dy)  # (dx, dgamma, dbeta)


# --------------------------------------------------------------------------
# Linear / fused GeLU-linear (MLP + projections)
# --------------------------------------------------------------------------

def linear_fwd(x, w, b):
    return linear(x, w, b)


def linear_bwd(x, w, b, dy):
    _, vjp = jax.vjp(lambda x, w, b: x @ w + b[None, :], x, w, b)
    return vjp(dy)  # (dx, dw, db)


def gelu_linear_fwd(x, w, b):
    return gelu_linear(x, w, b)


def gelu_linear_bwd(x, w, b, dy):
    _, vjp = jax.vjp(lambda x, w, b: ref.gelu(x @ w + b[None, :]), x, w, b)
    return vjp(dy)


def add(a, b):
    """Residual add (kept as its own artifact so the tensor-parallel engine
    can apply it AFTER the all-reduce of partial outputs)."""
    return a + b


def bias_add(y, b):
    """y[M, N] + b[N] — bias applied once after an all-reduce of partials."""
    return y + b[None, :]


def scale(x, s: float):
    """x * s — used for gradient averaging (1/N) after all-reduce."""
    return x * s


# --------------------------------------------------------------------------
# Head split / merge (layout lives in HLO, not rust)
# --------------------------------------------------------------------------

def to_heads(x, b: int, z: int, a: int):
    """[B*Lc, Z*A] -> [B, Z, Lc, A]."""
    m = x.shape[0]
    lc = m // b
    return x.reshape(b, lc, z, a).transpose(0, 2, 1, 3)


def from_heads(x):
    """[B, Z, Lc, A] -> [B*Lc, Z*A]."""
    b, z, lc, a = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b * lc, z * a)


# --------------------------------------------------------------------------
# Fused steps (§Perf iteration 2)
#
# At bert-tiny the PJRT per-call overhead (~190µs) dominated the step time
# (445 calls/step).  These fusions cut the call count ~30% without changing
# any semantics: the composed artifacts equal the composition of the small
# ones (pytest asserts so), and the engines stash exactly the same
# activations the paper's memory analysis counts.
# --------------------------------------------------------------------------

def qkv_proj(x, wq, bq, wk, bk, wv, bv, b: int, z: int, a: int):
    """Fused QKV projection + head split: 1 call instead of 6.

    x: [M, H] -> three [B, Z, Lc, A] tensors.
    """
    q = to_heads(linear(x, wq, bq), b, z, a)
    k = to_heads(linear(x, wk, bk), b, z, a)
    v = to_heads(linear(x, wv, bv), b, z, a)
    return q, k, v


def qkv_proj_bwd(x, wq, wk, wv, dq, dk, dv):
    """VJP of qkv_proj w.r.t. (x, weights, biases).

    dq/dk/dv arrive in head layout [B, Z, Lc, A]; returns
    (dx, dwq, dbq, dwk, dbk, dwv, dbv).
    """
    def f(x, wq, bq, wk, bk, wv, bv):
        return (x @ wq + bq[None, :], x @ wk + bk[None, :], x @ wv + bv[None, :])

    h = wq.shape[1]
    zeros = jnp.zeros((h,), jnp.float32)
    _, vjp = jax.vjp(f, x, wq, zeros, wk, zeros, wv, zeros)
    cots = (from_heads(dq), from_heads(dk), from_heads(dv))
    dx, dwq, dbq, dwk, dbk, dwv, dbv = vjp(cots)
    return dx, dwq, dbq, dwk, dbk, dwv, dbv


def add_ln_fwd(x, r, gamma, beta):
    """Residual add + LayerNorm fused; also returns the pre-LN sum, which
    the backward pass (plain ln_bwd) needs — same stash as unfused."""
    pre = x + r
    return layernorm(pre, gamma, beta), pre


def mlp_fwd(x, w1, b1, w2, b2):
    """Fused MLP block (Eq. 2): GeLU GEMM + second GEMM in one artifact."""
    return linear(gelu_linear(x, w1, b1), w2, b2)


def mlp_bwd(x, w1, b1, w2, b2, dy):
    """VJP of the MLP block; rematerializes the hidden activation inside
    (the engines no longer stash `h`, matching Megatron's recompute)."""
    _, vjp = jax.vjp(ref.mlp, x, w1, b1, w2, b2)
    return vjp(dy)  # (dx, dw1, db1, dw2, db2)


# --------------------------------------------------------------------------
# Ring Self-Attention — forward steps
# --------------------------------------------------------------------------

def scores_step(q, k):
    """One Ring-QK^T step: [B,Z,Lq,A] x [B,Z,Lk,A] -> [B,Z,Lq,Lk]."""
    return ring_scores(q, k)


def softmax_fwd(s):
    """Softmax over assembled rows [B,Z,Lc,L]."""
    return softmax_rows(s)


def av_step(p_i, v_i, acc):
    """One Ring-AV step: acc + p_i @ v_i."""
    return ring_av(p_i, v_i, acc)


# --------------------------------------------------------------------------
# Ring Self-Attention — backward steps (hand-scheduled; see module docs)
# --------------------------------------------------------------------------

def attn_dp_step(d_out, v_i):
    """dP_i = dO @ V_i^T : [B,Z,Lq,A] x [B,Z,Lk,A] -> [B,Z,Lq,Lk]."""
    return jnp.einsum("bzqa,bzka->bzqk", d_out, v_i)


def softmax_bwd(p, dp):
    """dS = P * (dP - rowsum(dP * P)) over full rows [B,Z,Lc,L]."""
    inner = jnp.sum(dp * p, axis=-1, keepdims=True)
    return p * (dp - inner)


def attn_dq_step(ds_i, k_i, dq_acc):
    """dQ += scale * dS_i @ K_i."""
    a = k_i.shape[-1]
    sc = 1.0 / jnp.sqrt(jnp.float32(a))
    return dq_acc + sc * jnp.einsum("bzqk,bzka->bzqa", ds_i, k_i)


def attn_dk_step(ds_i, q, dk_acc):
    """dK_i += scale * dS_i^T @ Q  (accumulator rides the ring)."""
    a = q.shape[-1]
    sc = 1.0 / jnp.sqrt(jnp.float32(a))
    return dk_acc + sc * jnp.einsum("bzqk,bzqa->bzka", ds_i, q)


def attn_dv_step(p_i, d_out, dv_acc):
    """dV_i += P_i^T @ dO  (accumulator rides the ring)."""
    return dv_acc + jnp.einsum("bzqk,bzqa->bzka", p_i, d_out)


# --------------------------------------------------------------------------
# Linformer (sparse-attention extension, paper §4.3 / Table 3)
# --------------------------------------------------------------------------

def linformer_proj_step(e, x):
    """Partial projection E^n X^n -> [B,Z,K,A]; all-reduced by L3."""
    return linformer_project(e, x)


def linformer_proj_bwd(e, x, dp):
    """VJP of the partial projection w.r.t. (e, x)."""
    _, vjp = jax.vjp(ref.linformer_project, e, x)
    return vjp(dp)


# --------------------------------------------------------------------------
# Loss heads (forward + grad fused into one artifact each)
# --------------------------------------------------------------------------

def _xent_logits(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]


def mlm_loss(x, w, b, labels, mask, norm: float):
    """Masked-LM loss over a local chunk, plus input/param grads.

    x: [M, H] final hidden states; w: [V, H]; b: [V]; labels: [M] int32;
    mask: [M] f32 (1.0 at masked positions); norm: GLOBAL normalizer
    (same constant on every device so that the all-reduced sum of
    per-device losses/grads is the true global mean — keeps seq-par,
    tensor-par and serial engines numerically identical).

    Returns (loss, dx, dw, db).
    """

    def f(x, w, b):
        logits = x @ w.T + b[None, :]
        per_tok = _xent_logits(logits, labels) * mask
        return jnp.sum(per_tok) / norm

    loss, grads = jax.value_and_grad(f, argnums=(0, 1, 2))(x, w, b)
    return (loss, *grads)


def sop_loss(x, w, b, labels, batch: int, norm: float):
    """Sentence-order-prediction loss from the CLS positions of a chunk.

    x: [M, H] — the FIRST device's final hidden chunk (position 0 of every
    sequence lives there under sequence parallelism; M = B * Lc); w: [2, H];
    b: [2]; labels: [B] int32.  The CLS rows are x[b * Lc] — extracted
    inside the artifact so the gradient dx lands back on the right rows.

    Returns (loss, dx, dw, db) with dx: [M, H] (zero except CLS rows).
    """
    m = x.shape[0]
    lc = m // batch

    def f(x, w, b):
        cls_h = x.reshape(batch, lc, -1)[:, 0, :]
        logits = cls_h @ w.T + b[None, :]
        return jnp.sum(_xent_logits(logits, labels)) / norm

    loss, grads = jax.value_and_grad(f, argnums=(0, 1, 2))(x, w, b)
    return (loss, *grads)


# --------------------------------------------------------------------------
# Optimizer (Adam step as an artifact: the update math runs in HLO too,
# so the rust hot path stays orchestration-only)
# --------------------------------------------------------------------------

def adam_step(p, g, m, v, lr, beta1: float, beta2: float, eps: float, t):
    """One Adam update.  lr: [] f32 (schedule computed in rust); t: [] f32
    step count (1-based).  Returns (p', m', v')."""
    m1 = beta1 * m + (1.0 - beta1) * g
    v1 = beta2 * v + (1.0 - beta2) * g * g
    mhat = m1 / (1.0 - beta1 ** t)
    vhat = v1 / (1.0 - beta2 ** t)
    return p - lr * mhat / (jnp.sqrt(vhat) + eps), m1, v1
