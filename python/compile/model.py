"""L2 — BERT model definition: parameter spec, init, and the monolithic
pure-jnp reference used for goldens and gradient cross-checks.

The *executed* model is the chain of ``steps.py`` artifacts that the rust
coordinator drives; this file defines (a) the parameter inventory that both
sides agree on (the manifest serializes it), (b) deterministic init so all
engines start from identical weights, and (c) the monolithic forward/loss
whose ``jax.grad`` is the ground truth the distributed chains must match.

Architecture: post-LN BERT (as Megatron-LM's BERT):

    x   = TokEmb[ids] + PosEmb
    per layer:
        a = MHA(x)                  # RSA under sequence parallelism
        x = LN1(x + a)
        m = W2 GeLU(W1 x)
        x = LN2(x + m)
    MLM head: logits = x W_mlm^T + b_mlm        (untied, as a linear head)
    SOP head: logits = cls W_sop^T + b_sop      (from the CLS position)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import steps
from .configs import ModelConfig
from .kernels import ref


# --------------------------------------------------------------------------
# Parameter inventory
# --------------------------------------------------------------------------

def param_spec(cfg: ModelConfig, seq_len: int):
    """Ordered (name, shape) list — the contract with the rust side.

    ``pos_emb`` is sized to the run's sequence length (each device loads its
    own slice; the monolithic reference uses the whole table).
    """
    h, f, v = cfg.hidden, cfg.ffn, cfg.vocab
    spec = [
        ("tok_emb", (v, h)),
        ("pos_emb", (seq_len, h)),
    ]
    for i in range(cfg.layers):
        p = f"layer{i}."
        spec += [
            (p + "wq", (h, h)), (p + "bq", (h,)),
            (p + "wk", (h, h)), (p + "bk", (h,)),
            (p + "wv", (h, h)), (p + "bv", (h,)),
            (p + "wo", (h, h)), (p + "bo", (h,)),
            (p + "ln1_g", (h,)), (p + "ln1_b", (h,)),
            (p + "w1", (h, f)), (p + "b1", (f,)),
            (p + "w2", (f, h)), (p + "b2", (h,)),
            (p + "ln2_g", (h,)), (p + "ln2_b", (h,)),
        ]
    spec += [
        ("mlm_w", (v, h)), ("mlm_b", (v,)),
        ("sop_w", (2, h)), ("sop_b", (2,)),
    ]
    return spec


def init_params(cfg: ModelConfig, seq_len: int, seed: int = 0):
    """Deterministic init: N(0, 0.02) weights, zero biases, unit LN gains."""
    spec = param_spec(cfg, seq_len)
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, shape in spec:
        key, sub = jax.random.split(key)
        if name.endswith(("_g",)) or name.endswith("ln1_g") or name.endswith("ln2_g"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif len(shape) == 1:
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            params[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
    return params


# --------------------------------------------------------------------------
# Monolithic reference (ground truth for every engine)
# --------------------------------------------------------------------------

def _lin(x, w, b):
    return x @ w + b[None, :]


def _mha(params, prefix, x, b: int, z: int, a: int):
    """Monolithic multi-head attention over the full sequence (pure jnp —
    this is the autodiff ground truth, so no Pallas calls here)."""
    q = steps.to_heads(_lin(x, params[prefix + "wq"], params[prefix + "bq"]), b, z, a)
    k = steps.to_heads(_lin(x, params[prefix + "wk"], params[prefix + "bk"]), b, z, a)
    v = steps.to_heads(_lin(x, params[prefix + "wv"], params[prefix + "bv"]), b, z, a)
    ctx = ref.attention(q, k, v)
    return _lin(steps.from_heads(ctx), params[prefix + "wo"], params[prefix + "bo"])


def forward(params, ids, cfg: ModelConfig):
    """Monolithic forward (pure jnp).  ids: [B, L] int32 -> [B*L, H]."""
    b, l = ids.shape
    z, a = cfg.heads, cfg.head_dim
    x = steps.embed_fwd(ids, params["tok_emb"], params["pos_emb"][:l])
    for i in range(cfg.layers):
        p = f"layer{i}."
        attn = _mha(params, p, x, b, z, a)
        x = ref.layernorm(x + attn, params[p + "ln1_g"], params[p + "ln1_b"])
        m = _lin(ref.gelu(_lin(x, params[p + "w1"], params[p + "b1"])),
                 params[p + "w2"], params[p + "b2"])
        x = ref.layernorm(x + m, params[p + "ln2_g"], params[p + "ln2_b"])
    return x


def loss(params, ids, labels, mask, sop_labels, cfg: ModelConfig):
    """Monolithic MLM + SOP loss (the quantity every engine must agree on).

    Normalizers: MLM by B*L (global constant — see steps.mlm_loss), SOP by B.
    Returns (total, mlm, sop).
    """
    b, l = ids.shape
    x = forward(params, ids, cfg)
    logits = x @ params["mlm_w"].T + params["mlm_b"][None, :]
    logp = jax.nn.log_softmax(logits, axis=-1)
    per_tok = -jnp.take_along_axis(logp, labels.reshape(-1)[:, None], axis=-1)[:, 0]
    mlm = jnp.sum(per_tok * mask.reshape(-1)) / float(b * l)

    cls = x.reshape(b, l, -1)[:, 0, :]
    sop_logits = cls @ params["sop_w"].T + params["sop_b"][None, :]
    slogp = jax.nn.log_softmax(sop_logits, axis=-1)
    sop = -jnp.mean(jnp.take_along_axis(slogp, sop_labels[:, None], axis=-1)[:, 0])
    return mlm + sop, mlm, sop


def grads(params, ids, labels, mask, sop_labels, cfg: ModelConfig):
    """jax.grad of the monolithic loss — gradient ground truth."""
    def f(p):
        return loss(p, ids, labels, mask, sop_labels, cfg)[0]
    return jax.grad(f)(params)
