"""Model configurations shared by the AOT pipeline and the tests.

The *simulated* experiments (figures/tables) use BERT-Base/Large exactly as
the paper; the *real-compute* path (artifacts executed by the rust runtime
on the CPU PJRT client) uses the small configs so the end-to-end example
finishes on one CPU host.  `bert-base` is still lowerable for anyone with
more compute (see examples/train_bert.rs --model).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    layers: int
    hidden: int          # H
    heads: int           # Z
    head_dim: int        # A  (H == Z * A for BERT)
    vocab: int
    max_len: int
    ffn_mult: int = 4

    @property
    def ffn(self) -> int:
        return self.ffn_mult * self.hidden

    def params(self) -> int:
        """Approximate parameter count (embeddings + blocks + heads)."""
        h, f, v = self.hidden, self.ffn, self.vocab
        per_layer = (
            4 * h * h + 4 * h          # qkv + out proj (weights + biases)
            + h * f + f + f * h + h    # mlp
            + 4 * h                    # two layernorms
        )
        emb = v * h + self.max_len * h
        heads = v * h + v + 2 * h + 2  # mlm head (untied) + sop head
        return emb + self.layers * per_layer + heads


CONFIGS = {
    # Paper models (used analytically by the simulator, lowerable on demand).
    "bert-base": ModelConfig("bert-base", 12, 768, 12, 64, 30522, 512),
    "bert-large": ModelConfig("bert-large", 24, 1024, 16, 64, 30522, 512),
    # Real-compute configs for the CPU testbed.
    "bert-small": ModelConfig("bert-small", 4, 256, 4, 64, 8192, 512),
    "bert-tiny": ModelConfig("bert-tiny", 2, 128, 2, 64, 1024, 256),
}

# Special token ids used by the synthetic corpus (rust/src/train/data.rs
# must agree with these).
PAD, CLS, SEP, MASK = 0, 1, 2, 3


def get(name: str) -> ModelConfig:
    try:
        return CONFIGS[name]
    except KeyError:
        raise KeyError(f"unknown model config {name!r}; have {sorted(CONFIGS)}")
