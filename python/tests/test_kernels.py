"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (and the f32 dtype the artifacts use); each case
asserts allclose against ref.py.  These tests are the core correctness
signal for the compute layer — if they are green, the HLO the rust runtime
executes is numerically the paper's computation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    gelu_linear,
    layernorm,
    linear,
    linformer_project,
    ring_av,
    ring_scores,
    softmax_rows,
)
from compile.kernels import common, ref

jax.config.update("jax_enable_x64", False)

SETTINGS = dict(max_examples=12, deadline=None)


def rand(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ---------------------------------------------------------------- ring_scores
@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    z=st.integers(1, 4),
    lq=st.sampled_from([4, 8, 16, 48]),
    lk=st.sampled_from([4, 8, 32]),
    a=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ring_scores_matches_ref(b, z, lq, lk, a, seed):
    kq, kk = keys(seed, 2)
    q = rand(kq, b, z, lq, a)
    k = rand(kk, b, z, lk, a)
    got = ring_scores(q, k)
    want = ref.scores(q, k)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_ring_scores_scaling():
    """Scores are scaled by 1/sqrt(A) exactly."""
    q = jnp.ones((1, 1, 4, 16), jnp.float32)
    k = jnp.ones((1, 1, 4, 16), jnp.float32)
    got = ring_scores(q, k)
    np.testing.assert_allclose(got, np.full((1, 1, 4, 4), 16 / 4.0), rtol=1e-6)


def test_ring_scores_rejects_mismatched_heads():
    q = jnp.zeros((1, 2, 4, 8), jnp.float32)
    k = jnp.zeros((1, 3, 4, 8), jnp.float32)
    with pytest.raises(ValueError):
        ring_scores(q, k)


# ------------------------------------------------------------------- ring_av
@settings(**SETTINGS)
@given(
    b=st.integers(1, 2),
    z=st.integers(1, 4),
    lq=st.sampled_from([4, 16, 48]),
    lk=st.sampled_from([4, 8, 32]),
    a=st.sampled_from([8, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ring_av_matches_ref(b, z, lq, lk, a, seed):
    ks, kv, ka = keys(seed, 3)
    s = rand(ks, b, z, lq, lk)
    v = rand(kv, b, z, lk, a)
    acc = rand(ka, b, z, lq, a)
    got = ring_av(s, v, acc)
    want = acc + ref.av(s, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_ring_av_zero_acc_is_plain_av():
    ks, kv = keys(7, 2)
    s = rand(ks, 1, 2, 8, 8)
    v = rand(kv, 1, 2, 8, 16)
    got = ring_av(s, v, jnp.zeros((1, 2, 8, 16), jnp.float32))
    np.testing.assert_allclose(got, ref.av(s, v), rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------- softmax
@settings(**SETTINGS)
@given(
    rows=st.sampled_from([1, 3, 8, 40]),
    width=st.sampled_from([2, 16, 512]),
    scale=st.sampled_from([1.0, 10.0, 100.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_softmax_matches_ref(rows, width, scale, seed):
    x = rand(keys(seed, 1)[0], rows, width) * scale
    got = softmax_rows(x)
    want = ref.softmax(x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_softmax_rows_sum_to_one():
    x = rand(keys(3, 1)[0], 4, 2, 8, 64)  # 4-d leading shape
    got = softmax_rows(x)
    np.testing.assert_allclose(np.sum(got, -1), np.ones((4, 2, 8)), rtol=1e-5)


def test_softmax_stable_at_large_magnitude():
    """No overflow for logits ~ 1e4 (the stable-max path)."""
    x = jnp.array([[1e4, 1e4 - 1.0, 0.0]], jnp.float32)
    got = np.asarray(softmax_rows(x))
    assert np.all(np.isfinite(got))
    np.testing.assert_allclose(got, ref.softmax(x), rtol=1e-5, atol=1e-7)


# ----------------------------------------------------------------------- mlp
@settings(**SETTINGS)
@given(
    m=st.sampled_from([4, 16, 96]),
    h=st.sampled_from([8, 32, 128]),
    n=st.sampled_from([8, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gelu_linear_matches_ref(m, h, n, seed):
    kx, kw, kb = keys(seed, 3)
    x, w, b = rand(kx, m, h), rand(kw, h, n), rand(kb, n)
    got = gelu_linear(x, w, b)
    want = ref.gelu(x @ w + b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    m=st.sampled_from([4, 96]),
    h=st.sampled_from([8, 128]),
    n=st.sampled_from([8, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_linear_matches_ref(m, h, n, seed):
    kx, kw, kb = keys(seed, 3)
    x, w, b = rand(kx, m, h), rand(kw, h, n), rand(kb, n)
    np.testing.assert_allclose(linear(x, w, b), x @ w + b, rtol=1e-4, atol=1e-4)


def test_mlp_block_composition():
    """gelu_linear + linear compose to the paper's Eq. 2 MLP block."""
    kx, k1, k2, k3, k4 = keys(11, 5)
    x = rand(kx, 32, 64)
    w1, b1 = rand(k1, 64, 256), rand(k2, 256)
    w2, b2 = rand(k3, 256, 64), rand(k4, 64)
    got = linear(gelu_linear(x, w1, b1), w2, b2)
    np.testing.assert_allclose(got, ref.mlp(x, w1, b1, w2, b2), rtol=1e-4, atol=1e-3)


# ----------------------------------------------------------------- layernorm
@settings(**SETTINGS)
@given(
    m=st.sampled_from([1, 8, 96]),
    h=st.sampled_from([8, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_layernorm_matches_ref(m, h, seed):
    kx, kg, kb = keys(seed, 3)
    x = rand(kx, m, h)
    g = rand(kg, h)
    b = rand(kb, h)
    np.testing.assert_allclose(
        layernorm(x, g, b), ref.layernorm(x, g, b), rtol=1e-4, atol=1e-4
    )


def test_layernorm_output_stats():
    """With unit gamma / zero beta, rows are standardized."""
    x = rand(keys(5, 1)[0], 16, 128) * 3.0 + 7.0
    out = np.asarray(layernorm(x, jnp.ones(128), jnp.zeros(128)))
    np.testing.assert_allclose(out.mean(-1), np.zeros(16), atol=1e-5)
    np.testing.assert_allclose(out.std(-1), np.ones(16), atol=1e-2)


# ----------------------------------------------------------------- linformer
@settings(**SETTINGS)
@given(
    b=st.integers(1, 2),
    z=st.integers(1, 3),
    lc=st.sampled_from([4, 16]),
    kproj=st.sampled_from([2, 8]),
    a=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_linformer_project_matches_ref(b, z, lc, kproj, a, seed):
    ke, kx = keys(seed, 2)
    e = rand(ke, kproj, lc)
    x = rand(kx, b, z, lc, a)
    np.testing.assert_allclose(
        linformer_project(e, x), ref.linformer_project(e, x), rtol=1e-4, atol=1e-4
    )


def test_linformer_partial_sum_equals_full_projection():
    """sum_n E^n X^n == E X — the identity the L3 all-reduce relies on."""
    n_dev, lc = 4, 8
    l = n_dev * lc
    ke, kx = keys(21, 2)
    e = rand(ke, 16, l)
    x = rand(kx, 2, 2, l, 32)
    full = ref.linformer_project(e, x)
    partial = sum(
        ref.linformer_project(
            e[:, i * lc:(i + 1) * lc], x[:, :, i * lc:(i + 1) * lc, :]
        )
        for i in range(n_dev)
    )
    np.testing.assert_allclose(partial, full, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- block math
@given(n=st.integers(1, 4096), cap=st.integers(1, 512))
@settings(max_examples=60, deadline=None)
def test_pick_block_divides(n, cap):
    b = common.largest_divisor_at_most(n, cap)
    assert n % b == 0 and 1 <= b <= min(n, cap)


def test_vmem_guard_rejects_oversized_blocks():
    with pytest.raises(ValueError):
        common.assert_fits_vmem("huge", (4096, 4096))  # 64 MiB > budget
