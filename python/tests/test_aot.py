"""AOT pipeline contract tests: naming, tensorio, artifact enumeration."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import aot, configs, steps, tensorio


# ------------------------------------------------------------------ tensorio
@settings(max_examples=20, deadline=None)
@given(
    shape=st.lists(st.integers(1, 8), min_size=0, max_size=4),
    integer=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_tensorio_roundtrip(shape, integer, seed):
    rng = np.random.default_rng(seed)
    if integer:
        arr = rng.integers(-1000, 1000, size=shape, dtype=np.int32)
    else:
        arr = rng.normal(size=shape).astype(np.float32)
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "t.tensor")
        tensorio.save(p, arr)
        back = tensorio.load(p)
    np.testing.assert_array_equal(back, arr)
    assert back.dtype == arr.dtype


def test_tensorio_rejects_bad_magic(tmp_path):
    p = tmp_path / "bad.tensor"
    p.write_bytes(b"NOPE" + b"\x00" * 16)
    with pytest.raises(ValueError):
        tensorio.load(p)


# ---------------------------------------------------------------- art naming
def test_art_name_matches_rust_registry():
    """These exact strings are asserted in rust/src/runtime/registry.rs —
    the two sides must never drift."""
    name = aot.art_name(
        "linear_fwd",
        [aot.spec([32, 128]), aot.spec([128, 512]), aot.spec([512])],
    )
    assert name == "linear_fwd__32x128_128x512_512"
    name = aot.art_name(
        "embed_fwd",
        [aot.spec([2, 16], jnp.int32), aot.spec([1024, 128]), aot.spec([16, 128])],
    )
    assert name == "embed_fwd__i2x16_1024x128_16x128"


# ---------------------------------------------------------- enumeration sanity
def test_enumerations_cover_every_step_the_engines_call():
    cfg = configs.get("bert-tiny")
    arts = aot.enumerate_seqpar(cfg, 2, 64, 4)
    names = {a[0] for a in arts}
    needed = {
        "embed_fwd", "embed_bwd", "ln_fwd", "ln_bwd", "linear_fwd", "linear_bwd",
        "gelu_linear_fwd", "gelu_linear_bwd", "to_heads_b2", "from_heads",
        "scores_step", "softmax_fwd", "av_step", "attn_dp_step", "softmax_bwd",
        "attn_dq_step", "attn_dk_step", "attn_dv_step", "add", "bias_add",
        "mlm_loss", "sop_loss",
    }
    missing = needed - names
    assert not missing, f"seqpar enumeration missing {missing}"

    tp = aot.enumerate_tensorpar(cfg, 2, 64, 2)
    tp_names = {a[0] for a in tp}
    assert needed - tp_names == set(), "tensorpar enumeration incomplete"


def test_seqpar_enumeration_shapes_are_chunked():
    cfg = configs.get("bert-tiny")
    arts = aot.enumerate_seqpar(cfg, 2, 64, 4)
    for step_name, _fn, specs in arts:
        if step_name == "scores_step":
            # q and k chunks: [B, Z, L/N, A]
            assert specs[0].shape == (2, cfg.heads, 16, cfg.head_dim)
        if step_name == "softmax_fwd":
            # assembled rows: full L width
            assert specs[0].shape[-1] == 64


def test_linformer_enumeration_projects_length():
    cfg = configs.get("bert-tiny")
    arts = aot.enumerate_linformer(cfg, 2, 64, 4, 16)
    by_name = {a[0]: a[2] for a in arts}
    assert by_name["linformer_proj"][0].shape == (16, 16)  # [K, Lc]
    assert by_name["softmax_fwd"][0].shape[-1] == 16       # rows are K wide


# ------------------------------------------------------------ dedup by name
def test_duplicate_shapes_dedup_to_one_artifact():
    cfg = configs.get("bert-tiny")
    arts = aot.enumerate_seqpar(cfg, 2, 64, 4) + aot.enumerate_seqpar(cfg, 2, 64, 4)
    names = [aot.art_name(s, sp) for s, _f, sp in arts]
    assert len(set(names)) < len(names)  # duplicates exist pre-dedup
    # lower_all dedups via the manifest dict — simulate
    manifest = {"artifacts": {}}
    seen = set()
    for n in names:
        if n in manifest["artifacts"]:
            continue
        manifest["artifacts"][n] = True
        seen.add(n)
    assert len(seen) == len(set(names))


# ----------------------------------------------------- loss normalizer logic
def test_mlm_loss_normalizer_makes_chunks_additive():
    """sum of per-chunk losses (norm = B*L_global) == monolithic mean —
    the property the rust engines' loss aggregation relies on."""
    key = jax.random.PRNGKey(0)
    b, l, h, v = 2, 8, 16, 32
    x = jax.random.normal(key, (b * l, h))
    w = jax.random.normal(jax.random.PRNGKey(1), (v, h))
    bias = jnp.zeros(v)
    labels = jax.random.randint(jax.random.PRNGKey(2), (b * l,), 0, v)
    mask = jnp.ones(b * l)
    full, *_ = steps.mlm_loss(x, w, bias, labels, mask, float(b * l))
    # chunked along tokens (per-batch-row blocks of l/2)
    half = b * l // 2
    lo1, *_ = steps.mlm_loss(x[:half], w, bias, labels[:half], mask[:half], float(b * l))
    lo2, *_ = steps.mlm_loss(x[half:], w, bias, labels[half:], mask[half:], float(b * l))
    np.testing.assert_allclose(lo1 + lo2, full, rtol=1e-5)
