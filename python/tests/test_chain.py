"""Schedule validation: the distributed chains vs monolithic ground truth.

``chain.py`` executes the exact step/comm schedules the rust engines run.
If these tests are green, every schedule bug left can only be a rust
transcription bug — which the rust integration tests then catch against
goldens exported from this same chain.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import chain, model, steps
from compile.configs import ModelConfig
from compile.kernels import ref

CFG = ModelConfig("test-tiny", layers=2, hidden=64, heads=2, head_dim=32,
                  vocab=128, max_len=64)


def make_batch(b=2, l=16, seed=0):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    ids = jax.random.randint(k1, (b, l), 4, CFG.vocab)
    labels = jax.random.randint(k2, (b, l), 4, CFG.vocab)
    mask = (jax.random.uniform(k3, (b, l)) < 0.15).astype(jnp.float32)
    sop = jax.random.randint(k4, (b,), 0, 2)
    return ids, labels, mask, sop


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, seq_len=16, seed=1)


@pytest.fixture(scope="module")
def batch():
    return make_batch()


# ------------------------------------------------------------------ RSA ring
@pytest.mark.parametrize("n_dev", [1, 2, 4])
def test_ring_attention_equals_monolithic(n_dev):
    """ref.ring_attention (the L2 oracle) == monolithic attention."""
    key = jax.random.PRNGKey(3)
    kq, kk, kv = jax.random.split(key, 3)
    b, z, l, a = 2, 2, 16, 8
    q = jax.random.normal(kq, (b, z, l, a))
    k = jax.random.normal(kk, (b, z, l, a))
    v = jax.random.normal(kv, (b, z, l, a))
    lc = l // n_dev
    qc = [q[:, :, i * lc:(i + 1) * lc] for i in range(n_dev)]
    kc = [k[:, :, i * lc:(i + 1) * lc] for i in range(n_dev)]
    vc = [v[:, :, i * lc:(i + 1) * lc] for i in range(n_dev)]
    outs = ref.ring_attention(qc, kc, vc)
    want = ref.attention(q, k, v)
    got = jnp.concatenate(outs, axis=2)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_rsa_backward_matches_jax_grad():
    """The hand-scheduled RSA backward == jax.grad of monolithic attention."""
    key = jax.random.PRNGKey(5)
    kq, kk, kv, kd = jax.random.split(key, 4)
    b, z, l, a, n_dev = 1, 2, 8, 16, 4
    q = jax.random.normal(kq, (b, z, l, a))
    k = jax.random.normal(kk, (b, z, l, a))
    v = jax.random.normal(kv, (b, z, l, a))
    d_out = jax.random.normal(kd, (b, z, l, a))

    def f(q, k, v):
        return jnp.sum(ref.attention(q, k, v) * d_out)

    want_dq, want_dk, want_dv = jax.grad(f, argnums=(0, 1, 2))(q, k, v)

    lc = l // n_dev
    qc = [q[:, :, i * lc:(i + 1) * lc] for i in range(n_dev)]
    kc = [k[:, :, i * lc:(i + 1) * lc] for i in range(n_dev)]
    vc = [v[:, :, i * lc:(i + 1) * lc] for i in range(n_dev)]
    dc = [d_out[:, :, i * lc:(i + 1) * lc] for i in range(n_dev)]

    dq = [None] * n_dev
    dk = [jnp.zeros_like(kc[i]) for i in range(n_dev)]
    dv = [jnp.zeros_like(vc[i]) for i in range(n_dev)]
    for dev in range(n_dev):
        _, p = chain._rsa_forward(qc[dev], kc[dev], vc[dev], n_dev, dev, kc, vc)
        dqd, dkc_, dvc_ = chain._rsa_backward(dc[dev], qc[dev], p, kc, vc, n_dev, dev)
        dq[dev] = dqd
        for i in range(n_dev):
            dk[i] = dk[i] + dkc_[i]
            dv[i] = dv[i] + dvc_[i]

    np.testing.assert_allclose(jnp.concatenate(dq, 2), want_dq, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(jnp.concatenate(dk, 2), want_dk, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(jnp.concatenate(dv, 2), want_dv, rtol=1e-4, atol=1e-4)


# --------------------------------------------------------- seq-par full model
@pytest.mark.parametrize("n_dev", [1, 2, 4])
def test_seqpar_loss_matches_monolithic(params, batch, n_dev):
    ids, labels, mask, sop = batch
    want, want_mlm, want_sop = model.loss(params, ids, labels, mask, sop, CFG)
    res = chain.seqpar_forward_backward(params, ids, labels, mask, sop, CFG, n_dev)
    np.testing.assert_allclose(res.mlm, want_mlm, rtol=1e-4)
    np.testing.assert_allclose(res.sop, want_sop, rtol=1e-4)
    np.testing.assert_allclose(res.loss, want, rtol=1e-4)


@pytest.mark.parametrize("n_dev", [2, 4])
def test_seqpar_hidden_matches_monolithic(params, batch, n_dev):
    ids, labels, mask, sop = batch
    want = model.forward(params, ids, CFG)
    res = chain.seqpar_forward_backward(params, ids, labels, mask, sop, CFG, n_dev)
    b, l = ids.shape
    got = jnp.concatenate(
        [h.reshape(b, l // n_dev, -1) for h in res.hidden_chunks], axis=1
    ).reshape(b * l, -1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n_dev", [2, 4])
def test_seqpar_grads_match_jax_grad(params, batch, n_dev):
    """The paper's implicit claim (Fig. 6): seq-par training == serial
    training.  We check it exactly: every parameter gradient matches."""
    ids, labels, mask, sop = batch
    want = model.grads(params, ids, labels, mask, sop, CFG)
    res = chain.seqpar_forward_backward(params, ids, labels, mask, sop, CFG, n_dev)
    for name in want:
        np.testing.assert_allclose(
            res.grads[name], want[name], rtol=2e-3, atol=2e-4,
            err_msg=f"grad mismatch for {name} at n_dev={n_dev}",
        )


# ----------------------------------------------------- tensor-par full model
@pytest.mark.parametrize("n_dev", [1, 2])
def test_tensorpar_loss_matches_monolithic(params, batch, n_dev):
    ids, labels, mask, sop = batch
    want, want_mlm, want_sop = model.loss(params, ids, labels, mask, sop, CFG)
    total, mlm, sop_l, hidden, _ = chain.tensorpar_forward_backward(
        params, ids, labels, mask, sop, CFG, n_dev)
    np.testing.assert_allclose(mlm, want_mlm, rtol=1e-4)
    np.testing.assert_allclose(sop_l, want_sop, rtol=1e-4)
    want_h = model.forward(params, ids, CFG)
    np.testing.assert_allclose(hidden, want_h, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n_dev", [2])
def test_tensorpar_grads_match_jax_grad(params, batch, n_dev):
    ids, labels, mask, sop = batch
    want = model.grads(params, ids, labels, mask, sop, CFG)
    *_, g = chain.tensorpar_forward_backward(params, ids, labels, mask, sop, CFG, n_dev)
    for name in want:
        np.testing.assert_allclose(
            g[name], want[name], rtol=2e-3, atol=2e-4,
            err_msg=f"grad mismatch for {name} at tp={n_dev}",
        )


# ------------------------------------------------------------------ adam step
def test_adam_step_matches_reference():
    key = jax.random.PRNGKey(9)
    p = jax.random.normal(key, (32,))
    gr = jax.random.normal(jax.random.PRNGKey(10), (32,))
    m = jnp.zeros(32)
    v = jnp.zeros(32)
    lr, b1, b2, eps = 1e-3, 0.9, 0.999, 1e-8
    p1, m1, v1 = steps.adam_step(p, gr, m, v, jnp.float32(lr), b1, b2, eps, jnp.float32(1.0))
    # closed form for t=1
    mhat = gr  # m1/(1-b1) = (1-b1)g/(1-b1)
    vhat = gr * gr
    np.testing.assert_allclose(p1, p - lr * mhat / (jnp.sqrt(vhat) + eps),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(m1, (1 - b1) * gr, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(v1, (1 - b2) * gr * gr, rtol=1e-5, atol=1e-7)
