"""§Perf iteration 2: fused steps must equal the composition of the small
steps they replace (semantics-preserving call-count optimization)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import steps


def rand(seed, *shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


B, Z, LC, A, H, F = 2, 2, 8, 16, 32, 64


def test_qkv_proj_equals_composition():
    x = rand(0, B * LC, H)
    ws = [rand(i + 1, H, Z * A) for i in range(3)]
    bs = [rand(i + 4, Z * A) for i in range(3)]
    q, k, v = steps.qkv_proj(x, ws[0], bs[0], ws[1], bs[1], ws[2], bs[2], b=B, z=Z, a=A)
    for got, w, bias in zip((q, k, v), ws, bs):
        want = steps.to_heads(x @ w + bias[None, :], B, Z, A)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_qkv_proj_bwd_matches_jax_grad():
    x = rand(0, B * LC, H)
    ws = [rand(i + 1, H, Z * A) for i in range(3)]
    bs = [jnp.zeros(Z * A) for _ in range(3)]
    dq, dk, dv = (rand(10 + i, B, Z, LC, A) for i in range(3))

    def f(x, wq, bq, wk, bk, wv, bv):
        q = steps.to_heads(x @ wq + bq[None, :], B, Z, A)
        k = steps.to_heads(x @ wk + bk[None, :], B, Z, A)
        v = steps.to_heads(x @ wv + bv[None, :], B, Z, A)
        return jnp.sum(q * dq) + jnp.sum(k * dk) + jnp.sum(v * dv)

    want = jax.grad(f, argnums=(0, 1, 2, 3, 4, 5, 6))(x, ws[0], bs[0], ws[1], bs[1], ws[2], bs[2])
    got = steps.qkv_proj_bwd(x, ws[0], ws[1], ws[2], dq, dk, dv)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-4)


def test_add_ln_equals_composition():
    x, r = rand(0, B * LC, H), rand(1, B * LC, H)
    g, b = rand(2, H), rand(3, H)
    y, pre = steps.add_ln_fwd(x, r, g, b)
    np.testing.assert_allclose(pre, x + r, rtol=1e-6)
    np.testing.assert_allclose(y, steps.ln_fwd(x + r, g, b), rtol=1e-5, atol=1e-5)


def test_mlp_fwd_bwd_match_composition():
    x = rand(0, B * LC, H)
    w1, b1 = rand(1, H, F), rand(2, F)
    w2, b2 = rand(3, F, H), rand(4, H)
    got = steps.mlp_fwd(x, w1, b1, w2, b2)
    want = steps.linear_fwd(steps.gelu_linear_fwd(x, w1, b1), w2, b2)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    dy = rand(5, B * LC, H)

    def f(x, w1, b1, w2, b2):
        from compile.kernels import ref
        return jnp.sum(ref.mlp(x, w1, b1, w2, b2) * dy)

    want_g = jax.grad(f, argnums=(0, 1, 2, 3, 4))(x, w1, b1, w2, b2)
    got_g = steps.mlp_bwd(x, w1, b1, w2, b2, dy)
    for g, w in zip(got_g, want_g):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-4)
